//! Ablation studies for the design choices DESIGN.md calls out — beyond
//! the paper's own figures:
//!
//! * **GO-capacity sweep** — shrink the score cache below the prefill
//!   capacity and measure routing agreement with the full router plus the
//!   DRAM traffic saved (the accuracy/storage knob of §III-C);
//! * **broadcast-bus ablation** — recount Algorithm 1's transfers with the
//!   shared bus disabled, isolating how much of the reschedule win is
//!   alignment vs local latching;
//! * **DRAM-bandwidth sensitivity** — how Fig. 4's headline ratios move
//!   with the cache-stream bandwidth;
//! * **adversarial grouping** — the worst-case pairing as a lower bound,
//!   showing what the sorted heuristic protects against;
//! * **noise sweep** — routing-decision flip rate vs analog noise level
//!   (the paper's future-work axis, `hw::noise`).

use crate::cache::GoCache;
use crate::config::{
    GroupingPolicy, HardwareConfig, MoeModelConfig, RoutingMode,
    SchedulePolicy, SimConfig,
};
use crate::grouping::Grouping;
use crate::hw::noise::NoiseModel;
use crate::moe::gate::expert_choice_route;
use crate::moe::TraceGenerator;
use crate::sched;
use crate::sim::Simulator;
use crate::util::rng::Pcg32;

// ---------------------------------------------------------------------------
// GO capacity sweep
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct CapacityRow {
    pub capacity: usize,
    /// fraction of the full-capacity routing's total gate mass still
    /// served at this capacity (a shrunken top-k' keeps the *heaviest*
    /// selections, so mass coverage decays much slower than k'/k — the
    /// curve that justifies shrinking the 512 KB output cache)
    pub gate_mass_coverage: f64,
    /// static output-cache bytes at this capacity
    pub cache_bytes: u64,
}

pub fn go_capacity_sweep(full_cap: usize, tokens: usize, seed: u64)
    -> Vec<CapacityRow> {
    let e = 16;
    let d = 4096;
    let mut rng = Pcg32::new(seed);
    let scores: Vec<f32> =
        (0..tokens * e).map(|_| rng.gen_normal() as f32).collect();
    let reference = expert_choice_route(&scores, tokens, e, full_cap, None);

    (1..=full_cap)
        .map(|cap| {
            // stream through a cache of this capacity
            let prefix = cap.max(1);
            let pre =
                expert_choice_route(&scores[..prefix * e], prefix, e, cap,
                                    None);
            let mut cache = GoCache::new(e, cap, 0);
            cache.seed_from_routing(&pre);
            for t in prefix..tokens {
                cache.update_scores(t, &scores[t * e..(t + 1) * e]);
            }
            // gate-mass coverage against the full-capacity reference
            let mut kept = 0f64;
            let mut total = 0f64;
            for x in 0..e {
                let got = cache.selected_tokens(x);
                for t in reference.choices.tokens_of(x) {
                    let w = reference.gate(t, x) as f64;
                    total += w;
                    if got.contains(&t) {
                        kept += w;
                    }
                }
            }
            CapacityRow {
                capacity: cap,
                gate_mass_coverage: kept / total,
                cache_bytes: GoCache::output_cache_bytes(cap, e, d),
            }
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Broadcast-bus ablation
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct BusRow {
    pub policy: &'static str,
    pub transfers_bus: usize,
    pub transfers_no_bus: usize,
}

pub fn bus_ablation(tokens: usize, seed: u64) -> Vec<BusRow> {
    let mut gen = TraceGenerator::new(16, seed);
    let choices = gen.token_choice_zipf(tokens, 4, 0.35);
    let grouping = Grouping::uniform(16, 2, seed);
    [("tokenwise", SchedulePolicy::TokenWise),
     ("compact", SchedulePolicy::Compact),
     ("reschedule", SchedulePolicy::Reschedule)]
        .into_iter()
        .map(|(name, p)| {
            let s = sched::build(&choices, &grouping, p);
            BusRow {
                policy: name,
                transfers_bus: s.transfers(),
                transfers_no_bus: s.transfers_local_only(),
            }
        })
        .collect()
}

// ---------------------------------------------------------------------------
// DRAM-bandwidth sensitivity of the Fig. 4 headline
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct BwRow {
    pub bytes_per_ns: f64,
    pub kvgo_latency_x: f64,
}

pub fn dram_bw_sensitivity(gen_len: usize) -> Vec<BwRow> {
    [2.0, 5.94, 12.8, 25.6, 102.4]
        .into_iter()
        .map(|bw| {
            let run = |kv: bool, go: bool| {
                let mut hw = HardwareConfig::paper();
                hw.dram.bytes_per_ns = bw;
                let mut cfg = SimConfig::baseline();
                cfg.cache.kv = kv;
                cfg.cache.go = go;
                cfg.gen_len = gen_len;
                Simulator::new(MoeModelConfig::llama_moe_4_16(), hw, cfg)
                    .run()
                    .decode_total()
                    .latency_ns
            };
            BwRow {
                bytes_per_ns: bw,
                kvgo_latency_x: run(false, false) / run(true, true),
            }
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Adversarial grouping
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct GroupingRow {
    pub policy: String,
    pub prefill_moe_ns: f64,
}

pub fn grouping_ablation(seed: u64) -> Vec<GroupingRow> {
    let run = |label: &str, grouping: Grouping| {
        let mut cfg = SimConfig::named(GroupingPolicy::Uniform, 2,
                                       SchedulePolicy::Reschedule);
        cfg.routing = RoutingMode::TokenChoice;
        cfg.skew = 0.8;
        cfg.gen_len = 0;
        cfg.seed = seed;
        let sim = Simulator::paper(cfg);
        let scores = sim.workload_scores();
        let routing = sim.route_batch(&scores, 32);
        let m = sim.prefill(&routing, &grouping);
        GroupingRow {
            policy: label.to_string(),
            prefill_moe_ns: m.breakdown.moe_ns,
        }
    };

    // derive loads once (same calibration stream the simulator uses)
    let mut gen = TraceGenerator::new(16, seed ^ 0xCA11B5A7E);
    let loads = gen.calibration_loads(8, 64, 4, 0.8);
    let mut order: Vec<usize> = (0..16).collect();
    order.sort_by(|&a, &b| loads[a].partial_cmp(&loads[b]).unwrap());
    // adversarial: heaviest with heaviest
    let adversarial: Vec<Vec<usize>> =
        order.chunks(2).map(|c| c.to_vec()).collect();

    vec![
        run("sorted", Grouping::sorted(&loads, 2)),
        run("uniform", Grouping::uniform(16, 2, seed)),
        run("adversarial", Grouping::custom(adversarial)),
    ]
}

// ---------------------------------------------------------------------------
// Noise sweep (future-work extension)
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct NoiseRow {
    pub sigma_adc_steps: f64,
    pub snr_db: f64,
    pub flip_rate: f64,
}

pub fn noise_sweep() -> Vec<NoiseRow> {
    [0.0, 0.2, 0.4, 1.0, 2.0]
        .into_iter()
        .map(|sigma| {
            let n = NoiseModel {
                sigma0_adc_steps: sigma,
                drift_rate: 0.0,
                t_hours: 0.0,
            };
            NoiseRow {
                sigma_adc_steps: sigma,
                snr_db: n.expected_snr_db(42.0),
                flip_rate: n.routing_flip_rate(32, 16, 8, 0.05, 6, 11),
            }
        })
        .collect()
}

pub fn render() -> String {
    let mut out = String::from("Ablations (extensions beyond the paper)\n");

    out += "\nGO capacity sweep (gate-mass coverage vs full capacity):\n";
    for r in go_capacity_sweep(8, 96, 3) {
        out += &format!("  k={:<2} coverage {:>6.1}%  cache {:>7} B\n",
                        r.capacity, r.gate_mass_coverage * 100.0,
                        r.cache_bytes);
    }

    out += "\nbroadcast-bus ablation (transfers, 32-token prefill):\n";
    for r in bus_ablation(32, 5) {
        out += &format!("  {:<10} with bus {:>4}   without {:>4}\n",
                        r.policy, r.transfers_bus, r.transfers_no_bus);
    }

    out += "\nDRAM bandwidth sensitivity (KVGO latency win @8 tokens):\n";
    for r in dram_bw_sensitivity(8) {
        out += &format!("  {:>6.1} B/ns -> {:.2}x\n", r.bytes_per_ns,
                        r.kvgo_latency_x);
    }

    out += "\ngrouping ablation (prefill MoE ns):\n";
    for r in grouping_ablation(7) {
        out += &format!("  {:<12} {:>8.0} ns\n", r.policy, r.prefill_moe_ns);
    }

    out += "\nanalog-noise sweep (routing flips, paper future work):\n";
    for r in noise_sweep() {
        out += &format!("  sigma {:>4.1} steps  snr {:>6.1} dB  flips \
                         {:>6.2}%\n",
                        r.sigma_adc_steps, r.snr_db, r.flip_rate * 100.0);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capacity_sweep_concave_coverage() {
        let rows = go_capacity_sweep(8, 64, 1);
        assert_eq!(rows.len(), 8);
        assert!(rows.last().unwrap().gate_mass_coverage > 0.999,
                "full capacity agrees exactly");
        for w in rows.windows(2) {
            assert!(w[0].gate_mass_coverage <= w[1].gate_mass_coverage);
        }
        // heaviest-first: half the capacity keeps well over half the mass
        assert!(rows[3].gate_mass_coverage > 0.5);
        assert!(rows[0].cache_bytes < rows[7].cache_bytes);
    }

    #[test]
    fn bus_matters_most_for_reschedule() {
        let rows = bus_ablation(32, 2);
        let by = |p: &str| rows.iter().find(|r| r.policy == p).unwrap();
        // without the bus, aligned broadcasts degrade to per-lane fetches
        assert!(by("reschedule").transfers_no_bus
                >= by("reschedule").transfers_bus);
        // tokenwise relies on the bus the most (every token shared)
        assert!(by("tokenwise").transfers_no_bus
                > by("tokenwise").transfers_bus);
    }

    #[test]
    fn faster_dram_grows_the_win() {
        let rows = dram_bw_sensitivity(8);
        assert!(rows.last().unwrap().kvgo_latency_x
                > rows.first().unwrap().kvgo_latency_x);
    }

    #[test]
    fn sorted_beats_adversarial() {
        let rows = grouping_ablation(3);
        let by = |p: &str| {
            rows.iter().find(|r| r.policy == p).unwrap().prefill_moe_ns
        };
        assert!(by("sorted") <= by("adversarial"));
    }

    #[test]
    fn noise_sweep_shapes() {
        let rows = noise_sweep();
        assert_eq!(rows[0].flip_rate, 0.0);
        assert!(rows.last().unwrap().flip_rate > rows[1].flip_rate);
    }

    #[test]
    fn renders() {
        let s = render();
        assert!(s.contains("GO capacity"));
        assert!(s.contains("noise"));
    }
}
