//! Crossbar-area-ratio generalisation (§IV-B text): with peripheral-heavy
//! designs (ISAAC-like, crossbar = 5 % of core area [20]) larger groups pay
//! off more — the paper reports 82.7 GOPS/mm² at group size 4 under a 5 %
//! ratio.  This sweep regenerates area efficiency across ratios and group
//! sizes.

use crate::config::{
    GroupingPolicy, HardwareConfig, MoeModelConfig, RoutingMode,
    SchedulePolicy, SimConfig,
};
use crate::sim::Simulator;

#[derive(Debug, Clone)]
pub struct SweepRow {
    pub xbar_ratio: f64,
    pub group_size: usize,
    pub area_mm2: f64,
    pub latency_ns: f64,
    pub gops_per_mm2: f64,
}

pub fn sweep(ratios: &[f64], group_sizes: &[usize]) -> Vec<SweepRow> {
    let mut out = Vec::new();
    for &ratio in ratios {
        for &g in group_sizes {
            let mut hw = HardwareConfig::paper();
            hw.xbar_area_ratio = ratio;
            let mut cfg = if g <= 1 {
                SimConfig::baseline()
            } else {
                SimConfig::named(GroupingPolicy::Sorted, g,
                                 SchedulePolicy::Reschedule)
            };
            cfg.routing = RoutingMode::TokenChoice;
            cfg.skew = 1.0;
            cfg.gen_len = 0;
            let sim = Simulator::new(MoeModelConfig::llama_moe_4_16(), hw,
                                     cfg);
            let r = sim.run();
            out.push(SweepRow {
                xbar_ratio: ratio,
                group_size: g,
                area_mm2: r.moe_area_mm2,
                latency_ns: r.total().latency_ns,
                gops_per_mm2: r.gops_per_mm2(),
            });
        }
    }
    out
}

/// The paper's quoted operating point: ratio 5 %, group 4.
pub fn isaac_point() -> SweepRow {
    sweep(&[0.05], &[4]).pop().unwrap()
}

pub fn render() -> String {
    let ratios = [0.05, 0.10, 0.20, 0.40];
    let groups = [1usize, 2, 4];
    let rows = sweep(&ratios, &groups);
    let mut out = String::from(
        "Crossbar-area-ratio sweep — GOPS/mm² (paper: 82.7 at ratio 5%, \
         g=4)\n",
    );
    out += &format!("{:<8}", "ratio");
    for g in groups {
        out += &format!(" {:>12}", format!("g={g}"));
    }
    out += &format!(" {:>10}\n", "best g");
    for &ratio in &ratios {
        out += &format!("{:<8}", format!("{:.0}%", ratio * 100.0));
        let mut best = (0usize, f64::MIN);
        for &g in &groups {
            let r = rows
                .iter()
                .find(|r| r.xbar_ratio == ratio && r.group_size == g)
                .unwrap();
            if r.gops_per_mm2 > best.1 {
                best = (g, r.gops_per_mm2);
            }
            out += &format!(" {:>12.2}", r.gops_per_mm2);
        }
        out += &format!(" {:>10}\n", best.0);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smaller_ratio_amplifies_group_benefit() {
        // at 40% crossbar share g=2 is near-optimal (paper); at 5% g=4 must
        // win area efficiency
        let rows = sweep(&[0.05, 0.40], &[1, 2, 4]);
        let eff = |ratio: f64, g: usize| {
            rows.iter()
                .find(|r| r.xbar_ratio == ratio && r.group_size == g)
                .unwrap()
                .gops_per_mm2
        };
        assert!(eff(0.05, 4) > eff(0.05, 1));
        assert!(eff(0.05, 4) > eff(0.05, 2), "g=4 wins at 5% ratio");
        // gain of g=4 over g=2 is larger at 5% than at 40%
        let gain_05 = eff(0.05, 4) / eff(0.05, 2);
        let gain_40 = eff(0.40, 4) / eff(0.40, 2);
        assert!(gain_05 > gain_40, "{gain_05} vs {gain_40}");
    }

    #[test]
    fn isaac_point_magnitude() {
        // same order of magnitude as the paper's 82.7 GOPS/mm²
        let p = isaac_point();
        assert!(p.gops_per_mm2 > 8.0 && p.gops_per_mm2 < 830.0,
                "{}", p.gops_per_mm2);
    }

    #[test]
    fn area_shrinks_with_ratio_and_group() {
        let rows = sweep(&[0.05, 0.40], &[1, 4]);
        let area = |ratio: f64, g: usize| {
            rows.iter()
                .find(|r| r.xbar_ratio == ratio && r.group_size == g)
                .unwrap()
                .area_mm2
        };
        assert!(area(0.05, 4) < area(0.05, 1));
        assert!(area(0.40, 4) < area(0.40, 1));
    }

    #[test]
    fn renders() {
        assert!(render().contains("ratio"));
    }
}
