//! Table I regenerator — total latency, energy and performance density for
//! {baseline; KVGO+S2O; KVGO+S4O} over a complete inference (32-token
//! prefill + 8 generated tokens).
//!
//! Paper targets: baseline 2,297,724 ns / 5,393,776 nJ / 10.2 GOPS/W/mm²;
//! S2O 3.20x latency and 4.92x energy improvement; S4O best density at
//! 15.6 GOPS/W/mm² (1.53x baseline).

use crate::config::SimConfig;
use crate::sim::Simulator;

#[derive(Debug, Clone)]
pub struct Table1Row {
    pub label: String,
    pub latency_ns: f64,
    pub energy_nj: f64,
    pub density: f64,
}

pub fn configs() -> Vec<(String, SimConfig)> {
    vec![
        ("No cache, No schedule".to_string(), SimConfig::baseline()),
        ("KVGO cache, S2O".to_string(), SimConfig::s2o_kvgo()),
        ("KVGO cache, S4O".to_string(), SimConfig::s4o_kvgo()),
    ]
}

pub fn table1() -> Vec<Table1Row> {
    configs()
        .into_iter()
        .map(|(label, cfg)| {
            let r = Simulator::paper(cfg).run();
            let t = r.total();
            Table1Row {
                label,
                latency_ns: t.latency_ns,
                energy_nj: t.energy_nj,
                density: r.density(),
            }
        })
        .collect()
}

/// Improvement ratios of the cached/scheduled configs over the baseline.
pub fn improvements(rows: &[Table1Row]) -> Vec<(String, f64, f64, f64)> {
    let base = &rows[0];
    rows.iter()
        .skip(1)
        .map(|r| {
            (
                r.label.clone(),
                base.latency_ns / r.latency_ns,
                base.energy_nj / r.energy_nj,
                r.density / base.density,
            )
        })
        .collect()
}

pub fn render() -> String {
    let rows = table1();
    let mut out = format!(
        "Table I — total latency, energy, density (paper: 2,297,724 ns / \
         5,393,776 nJ / 10.2 -> 12.3 -> 15.6 GOPS/W/mm²)\n\
         {:<24} {:>14} {:>14} {:>18}\n",
        "config", "latency(ns)", "energy(nJ)", "density(GOPS/W/mm2)"
    );
    for r in &rows {
        out += &format!(
            "{:<24} {:>14} {:>14} {:>18.1}\n",
            r.label,
            crate::util::fmt_thousands(r.latency_ns.round() as u64),
            crate::util::fmt_thousands(r.energy_nj.round() as u64),
            r.density
        );
    }
    for (label, lx, ex, dx) in improvements(&rows) {
        out += &format!(
            "{label}: {lx:.2}x latency, {ex:.2}x energy, {dx:.2}x density\n"
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn three_rows() {
        let rows = table1();
        assert_eq!(rows.len(), 3);
        assert!(rows[0].label.contains("No cache"));
    }

    #[test]
    fn cached_configs_beat_baseline() {
        let rows = table1();
        let imps = improvements(&rows);
        for (label, lx, ex, _) in &imps {
            assert!(*lx > 1.5, "{label} latency improvement {lx}");
            assert!(*ex > 1.5, "{label} energy improvement {ex}");
        }
    }

    #[test]
    fn s4o_has_best_density() {
        let rows = table1();
        assert!(rows[2].density > rows[1].density,
                "S4O {} vs S2O {}", rows[2].density, rows[1].density);
        // paper: 15.6 vs 10.2 (1.53x); our executed-ops accounting lands
        // S4O slightly above baseline — the ordering is what we pin
        assert!(rows[2].density > rows[0].density * 0.95,
                "S4O {} vs base {}", rows[2].density, rows[0].density);
    }

    #[test]
    fn s2o_has_best_latency() {
        // paper: "The best performance and energy of a complete inference
        // come from S2O with KVGO cache" (energies differ <1%: S2O 1,096,691
        // vs S4O 1,100,548 in the paper; we pin latency strictly and energy
        // within that same sliver)
        let rows = table1();
        assert!(rows[1].latency_ns <= rows[2].latency_ns);
        assert!(rows[1].energy_nj <= rows[2].energy_nj * 1.01);
    }

    #[test]
    fn renders() {
        let s = render();
        assert!(s.contains("Table I"));
        assert!(s.contains("S2O"));
    }
}
