//! Fig. 4 regenerators — the generation-stage cache study.
//!
//! * **Fig. 4(a)**: latency (and energy) of generating 8 tokens under
//!   {no cache, KV, GO, KVGO}, split into attention vs linear (gate+MoE)
//!   parts.  Headline claims: KVGO improves latency 4.2x and energy 10.1x
//!   over no-cache; 2.7x / 10.1x over KV-only.
//! * **Fig. 4(b)**: generate-stage latency vs generated length (8..64) per
//!   cache variant; the KVGO curve grows linearly while the baseline
//!   explodes (6.7x / 14.1x at 64 tokens).

use crate::config::{CachePolicy, SimConfig};
use crate::sim::Simulator;

pub const CACHE_VARIANTS: [CachePolicy; 4] = [
    CachePolicy::NONE,
    CachePolicy::KV,
    CachePolicy::GO,
    CachePolicy::KVGO,
];

/// One bar of Fig. 4(a): decode-stage totals for a cache variant.
#[derive(Debug, Clone)]
pub struct Fig4aRow {
    pub cache: &'static str,
    pub latency_ns: f64,
    pub energy_nj: f64,
    pub attn_ns: f64,
    pub linear_ns: f64,
    pub dram_ns: f64,
}

pub fn fig4a(gen_len: usize) -> Vec<Fig4aRow> {
    CACHE_VARIANTS
        .iter()
        .map(|&cache| {
            let mut cfg = SimConfig::baseline();
            cfg.cache = cache;
            cfg.gen_len = gen_len;
            let r = Simulator::paper(cfg).run();
            let d = r.decode_total();
            Fig4aRow {
                cache: cache.label(),
                latency_ns: d.latency_ns,
                energy_nj: d.energy_nj,
                attn_ns: d.breakdown.attn_ns,
                linear_ns: d.breakdown.gate_ns + d.breakdown.moe_ns,
                dram_ns: d.breakdown.dram_ns,
            }
        })
        .collect()
}

/// One series of Fig. 4(b): decode latency at each generated length.
#[derive(Debug, Clone)]
pub struct Fig4bSeries {
    pub cache: &'static str,
    pub lengths: Vec<usize>,
    pub latency_ns: Vec<f64>,
}

pub fn fig4b(lengths: &[usize]) -> Vec<Fig4bSeries> {
    CACHE_VARIANTS
        .iter()
        .map(|&cache| {
            let latency = lengths
                .iter()
                .map(|&n| {
                    let mut cfg = SimConfig::baseline();
                    cfg.cache = cache;
                    cfg.gen_len = n;
                    Simulator::paper(cfg).run().decode_total().latency_ns
                })
                .collect();
            Fig4bSeries {
                cache: cache.label(),
                lengths: lengths.to_vec(),
                latency_ns: latency,
            }
        })
        .collect()
}

/// The paper's headline improvement ratios (no-cache vs KVGO).
#[derive(Debug, Clone, Copy)]
pub struct CacheImprovement {
    pub latency_x: f64,
    pub energy_x: f64,
    /// vs KV-only
    pub latency_vs_kv_x: f64,
    pub energy_vs_kv_x: f64,
}

pub fn improvement(gen_len: usize) -> CacheImprovement {
    let rows = fig4a(gen_len);
    let by = |label: &str| {
        rows.iter().find(|r| r.cache == label).expect("variant missing")
    };
    let none = by("no cache");
    let kv = by("KV cache");
    let kvgo = by("KVGO cache");
    CacheImprovement {
        latency_x: none.latency_ns / kvgo.latency_ns,
        energy_x: none.energy_nj / kvgo.energy_nj,
        latency_vs_kv_x: kv.latency_ns / kvgo.latency_ns,
        energy_vs_kv_x: kv.energy_nj / kvgo.energy_nj,
    }
}

/// Render Fig. 4(a) as a text table (CLI + EXPERIMENTS.md).
pub fn render_fig4a(gen_len: usize) -> String {
    let rows = fig4a(gen_len);
    let mut out = format!(
        "Fig 4(a) — generate {gen_len} tokens (paper: KVGO 4.2x latency, \
         10.1x energy vs no cache at 8)\n\
         {:<12} {:>14} {:>14} {:>12} {:>12} {:>10}\n",
        "cache", "latency(ns)", "energy(nJ)", "attn(ns)", "linear(ns)",
        "dram(ns)"
    );
    for r in &rows {
        out += &format!(
            "{:<12} {:>14.0} {:>14.0} {:>12.0} {:>12.0} {:>10.0}\n",
            r.cache, r.latency_ns, r.energy_nj, r.attn_ns, r.linear_ns,
            r.dram_ns
        );
    }
    let imp = improvement(gen_len);
    out += &format!(
        "KVGO vs none: {:.1}x latency, {:.1}x energy;  vs KV: {:.1}x / {:.1}x\n",
        imp.latency_x, imp.energy_x, imp.latency_vs_kv_x, imp.energy_vs_kv_x
    );
    out
}

/// Render Fig. 4(b).
pub fn render_fig4b() -> String {
    let lengths = [8usize, 16, 24, 32, 40, 48, 56, 64];
    let series = fig4b(&lengths);
    let mut out = String::from(
        "Fig 4(b) — decode latency (ns) vs generated length\n",
    );
    out += &format!("{:<12}", "cache");
    for l in lengths {
        out += &format!(" {l:>12}");
    }
    out.push('\n');
    for s in &series {
        out += &format!("{:<12}", s.cache);
        for v in &s.latency_ns {
            out += &format!(" {v:>12.0}");
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4a_has_all_variants() {
        let rows = fig4a(8);
        assert_eq!(rows.len(), 4);
        let labels: Vec<&str> = rows.iter().map(|r| r.cache).collect();
        assert!(labels.contains(&"no cache") && labels.contains(&"KVGO cache"));
    }

    #[test]
    fn improvement_grows_with_length() {
        let i8 = improvement(8);
        let i64 = improvement(64);
        assert!(i8.latency_x > 1.0 && i8.energy_x > 1.0);
        assert!(i64.latency_x > i8.latency_x);
        assert!(i64.energy_x > i8.energy_x);
    }

    #[test]
    fn fig4b_series_monotone_in_length() {
        for s in fig4b(&[8, 32, 64]) {
            assert!(s.latency_ns[0] < s.latency_ns[1]);
            assert!(s.latency_ns[1] < s.latency_ns[2]);
        }
    }

    #[test]
    fn kv_reduces_attention_not_energy_much() {
        // paper: "KV cache reduces attention latency but does not benefit
        // from energy because DRAM costs extra energy"
        let rows = fig4a(8);
        let none = rows.iter().find(|r| r.cache == "no cache").unwrap();
        let kv = rows.iter().find(|r| r.cache == "KV cache").unwrap();
        assert!(kv.attn_ns < none.attn_ns);
        let energy_gain = none.energy_nj / kv.energy_nj;
        assert!(energy_gain < 2.0,
                "KV alone must not win much energy: {energy_gain}");
    }

    #[test]
    fn renders() {
        assert!(render_fig4a(8).contains("KVGO"));
        assert!(render_fig4b().contains("no cache"));
    }
}
