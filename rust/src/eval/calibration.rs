//! Calibration of the 3DCIM-substitute constants (DESIGN.md §8).
//!
//! The paper's simulator is closed; our digital-unit/DRAM constants in
//! [`crate::config::DigitalConfig`] and [`crate::config::DramConfig`] are
//! fitted so that the *published* numbers come out: Table I's baseline
//! column (absolute ns/nJ), Fig. 4's improvement ratios at 8 and 64
//! generated tokens, and Fig. 5's area-efficiency gain.  This module
//! computes every target in one place; `rust/tests/paper_claims.rs` pins
//! them with tolerance bands, and `moepim eval calibration` prints the
//! table for EXPERIMENTS.md.

use crate::eval::{fig4, fig5, table1};

/// One calibration target: paper value vs measured value.
#[derive(Debug, Clone)]
pub struct Target {
    pub name: &'static str,
    pub paper: f64,
    pub measured: f64,
}

impl Target {
    /// measured / paper (1.0 == exact).
    pub fn ratio(&self) -> f64 {
        self.measured / self.paper
    }

    pub fn within(&self, rel: f64) -> bool {
        self.ratio() >= 1.0 - rel && self.ratio() <= 1.0 + rel
    }
}

/// Compute all paper-vs-measured targets (E6 of DESIGN.md §5).
pub fn targets() -> Vec<Target> {
    let imp8 = fig4::improvement(8);
    let imp64 = fig4::improvement(64);
    let t1 = table1::table1();
    let t1imp = table1::improvements(&t1);
    let f5 = fig5::fig5();
    let (_, best_eff) = fig5::best_improvement(&f5);

    vec![
        Target { name: "fig4a latency x (8 tok, KVGO vs none)",
                 paper: 4.2, measured: imp8.latency_x },
        Target { name: "fig4a energy x (8 tok, KVGO vs none)",
                 paper: 10.1, measured: imp8.energy_x },
        Target { name: "fig4a latency x (8 tok, KVGO vs KV)",
                 paper: 2.7, measured: imp8.latency_vs_kv_x },
        Target { name: "fig4b latency x (64 tok)",
                 paper: 6.7, measured: imp64.latency_x },
        Target { name: "fig4b energy x (64 tok)",
                 paper: 14.1, measured: imp64.energy_x },
        Target { name: "table1 baseline latency (ns)",
                 paper: 2_297_724.0, measured: t1[0].latency_ns },
        Target { name: "table1 baseline energy (nJ)",
                 paper: 5_393_776.0, measured: t1[0].energy_nj },
        Target { name: "table1 S2O latency x",
                 paper: 3.20, measured: t1imp[0].1 },
        Target { name: "table1 S2O energy x",
                 paper: 4.92, measured: t1imp[0].2 },
        Target { name: "table1 S4O density x",
                 paper: 1.53, measured: t1imp[1].3 },
        Target { name: "table1 baseline density (GOPS/W/mm2)",
                 paper: 10.2, measured: t1[0].density },
        Target { name: "table1 S4O density (GOPS/W/mm2)",
                 paper: 15.6, measured: t1[2].density },
        Target { name: "fig5 best area-efficiency x",
                 paper: 2.2, measured: best_eff },
    ]
}

pub fn render() -> String {
    let mut out = format!(
        "Calibration — paper vs measured (DESIGN.md §8 constants)\n\
         {:<42} {:>12} {:>12} {:>8}\n",
        "target", "paper", "measured", "m/p"
    );
    for t in targets() {
        out += &format!(
            "{:<42} {:>12.1} {:>12.1} {:>8.2}\n",
            t.name, t.paper, t.measured, t.ratio()
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn targets_all_present() {
        let ts = targets();
        assert_eq!(ts.len(), 13);
        for t in &ts {
            assert!(t.measured.is_finite() && t.measured > 0.0, "{}", t.name);
        }
    }

    #[test]
    fn ratio_math() {
        let t = Target { name: "x", paper: 2.0, measured: 2.2 };
        assert!((t.ratio() - 1.1).abs() < 1e-12);
        assert!(t.within(0.15));
        assert!(!t.within(0.05));
    }
}
