//! Evaluation harness: one regenerator per paper artefact (Fig. 4a/4b,
//! Fig. 5, Table I, the crossbar-area-ratio sweep).  Each module exposes
//! structured rows (consumed by benches/tests) and a `render` function
//! (consumed by the CLI and EXPERIMENTS.md).

pub mod ablation;
pub mod calibration;
pub mod fig4;
pub mod fig5;
pub mod sweep;
pub mod table1;
