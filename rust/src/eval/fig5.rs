//! Fig. 5 regenerator — the grouping × scheduling study over the prefill
//! stage: {baseline; U/S × group 2/4 × C/O} with latency, energy and area
//! efficiency (GOPS/mm²).  Headline claim: S2O improves area efficiency by
//! up to 2.2x over the baseline; larger groups cut area but add contention
//! (g=2 wins at HERMES\'s 40 % crossbar-area ratio).
//!
//! Scope note: the figure reports the **MoE linear part** of prefill —
//! the quantity the grouping/scheduling methods act on (the digital-MHA
//! time is identical across all nine bars and would mask the effect; the
//! paper\'s §IV-A area scope is likewise "only the MoE linear cores").
//! Table I keeps whole-inference totals.
//!
//! Workload note (DESIGN.md §5/E3): the grouping study needs load variance
//! to differentiate U from S, so it runs the model\'s native token-choice
//! router over the skewed C4-substitute trace; the cache study (Fig. 4)
//! runs expert-choice, whose caches are the paper\'s §III-C contribution.

use crate::config::{
    GroupingPolicy, RoutingMode, SchedulePolicy, SimConfig,
};
use crate::sim::Simulator;

#[derive(Debug, Clone)]
pub struct Fig5Row {
    pub label: String,
    pub latency_ns: f64,
    pub energy_nj: f64,
    pub transfers: u64,
    pub area_mm2: f64,
    pub gops_per_mm2: f64,
}

/// The Fig. 5 sweep configurations, in the paper's bar order.
pub fn configs() -> Vec<SimConfig> {
    let mut out = vec![fig5_cfg(SimConfig::baseline())];
    for group_size in [2usize, 4] {
        for grouping in [GroupingPolicy::Uniform, GroupingPolicy::Sorted] {
            for schedule in
                [SchedulePolicy::Compact, SchedulePolicy::Reschedule]
            {
                out.push(fig5_cfg(SimConfig::named(
                    grouping, group_size, schedule,
                )));
            }
        }
    }
    out
}

fn fig5_cfg(mut cfg: SimConfig) -> SimConfig {
    cfg.routing = RoutingMode::TokenChoice;
    cfg.skew = 0.35;
    cfg.gen_len = 0; // prefill-stage study
    cfg
}

pub fn fig5() -> Vec<Fig5Row> {
    fig5_with(|c| c)
}

/// Workload seeds averaged per bar (single-trace makespans are noisy; the
/// paper likewise samples several C4 batches).
pub const FIG5_SEEDS: u64 = 8;

/// Sweep with a config hook (the ratio-sweep reuses this with ISAAC-style
/// hardware).  Each bar averages `FIG5_SEEDS` workload seeds.
pub fn fig5_with<F: Fn(SimConfig) -> SimConfig>(hook: F) -> Vec<Fig5Row> {
    configs()
        .into_iter()
        .map(|cfg| {
            let cfg = hook(cfg);
            let mut acc: Option<Fig5Row> = None;
            for s in 0..FIG5_SEEDS {
                let mut c = cfg.clone();
                c.seed = cfg.seed.wrapping_add(s * 7919);
                let row = row_for(&Simulator::paper(c));
                acc = Some(match acc {
                    None => row,
                    Some(mut a) => {
                        a.latency_ns += row.latency_ns;
                        a.energy_nj += row.energy_nj;
                        a.transfers += row.transfers;
                        a.gops_per_mm2 += row.gops_per_mm2;
                        a
                    }
                });
            }
            let mut r = acc.unwrap();
            let n = FIG5_SEEDS as f64;
            r.latency_ns /= n;
            r.energy_nj /= n;
            r.transfers = (r.transfers as f64 / n).round() as u64;
            r.gops_per_mm2 /= n;
            r
        })
        .collect()
}

pub fn row_for(sim: &Simulator) -> Fig5Row {
    let r = sim.run();
    let t = r.total();
    // MoE-part ops: PIM activations x crossbar MACs x 2
    let moe_ops = 2.0
        * (t.activations * sim.hw.macs_per_activation()) as f64;
    // linear-part energy includes the activation-broadcast cost
    let moe_nj = t.breakdown.moe_nj;
    Fig5Row {
        label: r.label.clone(),
        latency_ns: t.breakdown.moe_ns,
        energy_nj: moe_nj,
        transfers: t.transfers,
        area_mm2: r.moe_area_mm2,
        gops_per_mm2: moe_ops / t.breakdown.moe_ns / r.moe_area_mm2,
    }
}

/// Area-efficiency improvement of the best configuration over baseline
/// (paper: up to 2.2x, achieved by S2O).
pub fn best_improvement(rows: &[Fig5Row]) -> (String, f64) {
    let base = rows
        .iter()
        .find(|r| r.label == "base")
        .expect("baseline row present");
    rows.iter()
        .map(|r| (r.label.clone(), r.gops_per_mm2 / base.gops_per_mm2))
        .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .unwrap()
}

pub fn render() -> String {
    let rows = fig5();
    let mut out = format!(
        "Fig 5 — grouping x scheduling, 32-token prefill, MoE linear part \
         (paper: S2O up to 2.2x area efficiency)\n\
         {:<6} {:>12} {:>12} {:>10} {:>10} {:>12} {:>8}\n",
        "cfg", "latency(ns)", "energy(nJ)", "transfers", "area(mm2)",
        "GOPS/mm2", "vs base"
    );
    let base_eff = rows[0].gops_per_mm2;
    for r in &rows {
        out += &format!(
            "{:<6} {:>12.0} {:>12.0} {:>10} {:>10.1} {:>12.3} {:>7.2}x\n",
            r.label, r.latency_ns, r.energy_nj, r.transfers, r.area_mm2,
            r.gops_per_mm2, r.gops_per_mm2 / base_eff
        );
    }
    let (label, x) = best_improvement(&rows);
    out += &format!("best: {label} at {x:.2}x baseline area efficiency\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn by<'a>(rows: &'a [Fig5Row], label: &str) -> &'a Fig5Row {
        rows.iter().find(|r| r.label == label).expect(label)
    }

    #[test]
    fn has_all_nine_bars() {
        let rows = fig5();
        assert_eq!(rows.len(), 9);
        for l in ["base", "U2C", "U2O", "S2C", "S2O", "U4C", "U4O", "S4C",
                  "S4O"] {
            assert!(rows.iter().any(|r| r.label == l), "missing {l}");
        }
    }

    #[test]
    fn sharing_reduces_area() {
        let rows = fig5();
        assert!(by(&rows, "S2O").area_mm2 < by(&rows, "base").area_mm2);
        assert!(by(&rows, "S4O").area_mm2 < by(&rows, "S2O").area_mm2);
    }

    #[test]
    fn reschedule_never_worse_than_compact() {
        let rows = fig5();
        for (c, o) in [("U2C", "U2O"), ("S2C", "S2O"), ("U4C", "U4O"),
                       ("S4C", "S4O")] {
            assert!(by(&rows, o).transfers <= by(&rows, c).transfers);
            assert!(by(&rows, o).energy_nj <= by(&rows, c).energy_nj);
            assert!(
                (by(&rows, o).latency_ns - by(&rows, c).latency_ns).abs()
                    < 1e-6,
                "O keeps C latency"
            );
        }
    }

    #[test]
    fn sorted_not_worse_than_uniform() {
        let rows = fig5();
        for (u, s) in [("U2O", "S2O"), ("U4O", "S4O")] {
            assert!(
                by(&rows, s).latency_ns <= by(&rows, u).latency_ns * 1.001,
                "{s} vs {u}"
            );
        }
    }

    #[test]
    fn best_config_improves_area_efficiency() {
        // paper: "up to 2.2x"; our calibrated reproduction lands ~2x
        let rows = fig5();
        let (label, x) = best_improvement(&rows);
        assert!(x > 1.8, "sharing must pay off in GOPS/mm2, got {x:.2}");
        assert!(label.starts_with("S2"), "g=2 sorted wins at 40% ratio: {label}");
    }

    #[test]
    fn group2_beats_group4_at_hermes_ratio() {
        // §IV-B: "a group of two experts gained the best area efficiency
        // ... the crossbar area accounts for 40% of the total area"
        let rows = fig5();
        assert!(by(&rows, "S2O").gops_per_mm2 > by(&rows, "S4O").gops_per_mm2);
    }

    #[test]
    fn compact_reduces_latency_vs_tokenwise_baseline() {
        // §IV-B: "the compact schedule reduces the latency"
        let rows = fig5();
        for l in ["U2C", "S2C"] {
            assert!(by(&rows, l).latency_ns < by(&rows, "base").latency_ns
                    * 1.01, "{l}");
        }
    }
}
