//! Mini bench harness — in-tree substitute for criterion (offline image).
//!
//! `cargo bench` targets use `harness = false` and call [`Bench::run`]
//! directly.  The harness warms up, auto-tunes the iteration count to a
//! target sample time, collects per-sample wall-clock means, and prints a
//! criterion-flavoured `time: [lo mid hi]` line so existing tooling that
//! greps bench output keeps working.

use std::hint::black_box;
use std::time::{Duration, Instant};

pub use std::hint::black_box as bb;

#[derive(Debug, Clone)]
pub struct BenchConfig {
    pub warmup: Duration,
    pub sample_time: Duration,
    pub samples: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup: Duration::from_millis(300),
            sample_time: Duration::from_millis(120),
            samples: 20,
        }
    }
}

pub struct Bench {
    cfg: BenchConfig,
    group: String,
}

#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub lo_ns: f64,
    pub mid_ns: f64,
    pub hi_ns: f64,
}

impl Bench {
    pub fn new(group: &str) -> Bench {
        println!("\nbench group: {group}");
        Bench { cfg: BenchConfig::default(), group: group.to_string() }
    }

    pub fn with_config(group: &str, cfg: BenchConfig) -> Bench {
        println!("\nbench group: {group}");
        Bench { cfg, group: group.to_string() }
    }

    /// Benchmark `f`, printing a criterion-style line.  Returns the stats so
    /// callers can assert regressions.
    pub fn run<R, F: FnMut() -> R>(&self, name: &str, mut f: F) -> Stats {
        // Warmup + estimate single-iteration cost.
        let warm_start = Instant::now();
        let mut iters_done: u64 = 0;
        while warm_start.elapsed() < self.cfg.warmup {
            black_box(f());
            iters_done += 1;
        }
        let per_iter =
            warm_start.elapsed().as_secs_f64() / iters_done.max(1) as f64;
        let iters_per_sample = ((self.cfg.sample_time.as_secs_f64() / per_iter)
            .ceil() as u64)
            .max(1);

        let mut means = Vec::with_capacity(self.cfg.samples);
        for _ in 0..self.cfg.samples {
            let t = Instant::now();
            for _ in 0..iters_per_sample {
                black_box(f());
            }
            means.push(t.elapsed().as_secs_f64() * 1e9
                / iters_per_sample as f64);
        }
        means.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let stats = Stats {
            lo_ns: means[means.len() / 20],
            mid_ns: means[means.len() / 2],
            hi_ns: means[means.len() - 1 - means.len() / 20],
        };
        println!(
            "{}/{name}  time: [{} {} {}]  ({} it/sample)",
            self.group,
            fmt_ns(stats.lo_ns),
            fmt_ns(stats.mid_ns),
            fmt_ns(stats.hi_ns),
            iters_per_sample,
        );
        stats
    }

    /// Report a derived metric (e.g. simulated ns, GOPS) alongside timings —
    /// used by the figure benches to print the paper's numbers.
    pub fn metric(&self, name: &str, value: f64, unit: &str) {
        println!("{}/{name}  metric: {value:.4} {unit}", self.group);
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.2} ns")
    } else if ns < 1e6 {
        format!("{:.3} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.3} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn formats() {
        assert_eq!(fmt_ns(12.0), "12.00 ns");
        assert_eq!(fmt_ns(1500.0), "1.500 µs");
        assert_eq!(fmt_ns(2.5e6), "2.500 ms");
        assert_eq!(fmt_ns(3.1e9), "3.100 s");
    }

    #[test]
    fn runs_and_orders_stats() {
        let b = Bench::with_config(
            "test",
            BenchConfig {
                warmup: Duration::from_millis(5),
                sample_time: Duration::from_millis(2),
                samples: 5,
            },
        );
        let s = b.run("noop", || 1 + 1);
        assert!(s.lo_ns <= s.mid_ns && s.mid_ns <= s.hi_ns);
        assert!(s.mid_ns < 1e6); // a no-op is far under 1ms
    }
}
