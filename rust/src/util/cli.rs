//! Tiny argv parser — in-tree substitute for clap (offline image).
//!
//! Supports `subcommand --flag value --flag=value --bool-flag positional`.
//! The launcher (`main.rs`) defines its own usage text; this module only
//! tokenises and type-checks.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse `argv[1..]`.  The first non-flag token becomes the subcommand;
    /// later non-flag tokens are positional.  `--flag value` consumes the
    /// next token unless it starts with `--`; bare `--flag` stores "true".
    ///
    /// Ambiguity note: `--bool positional` reads the positional as the
    /// flag's value — write boolean flags last or as `--flag=true`.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let tokens: Vec<String> = argv.into_iter().collect();
        let mut args = Args::default();
        let mut i = 0;
        while i < tokens.len() {
            let tok = &tokens[i];
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if i + 1 < tokens.len()
                    && !tokens[i + 1].starts_with("--")
                {
                    args.flags
                        .insert(stripped.to_string(), tokens[i + 1].clone());
                    i += 1;
                } else {
                    args.flags.insert(stripped.to_string(), "true".into());
                }
            } else if args.subcommand.is_none() {
                args.subcommand = Some(tok.clone());
            } else {
                args.positional.push(tok.clone());
            }
            i += 1;
        }
        args
    }

    pub fn str_flag(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.into())
    }

    pub fn usize_flag(&self, key: &str, default: usize) -> usize {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn u64_flag(&self, key: &str, default: u64) -> u64 {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn f64_flag(&self, key: &str, default: f64) -> f64 {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn bool_flag(&self, key: &str) -> bool {
        matches!(self.flags.get(key).map(|s| s.as_str()), Some("true") | Some("1"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|t| t.to_string()))
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("simulate x --group-size 4 --sched=resched --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("simulate"));
        assert_eq!(a.usize_flag("group-size", 2), 4);
        assert_eq!(a.str_flag("sched", ""), "resched");
        assert!(a.bool_flag("verbose"));
        assert_eq!(a.positional, vec!["x"]);
    }

    #[test]
    fn defaults() {
        let a = parse("eval");
        assert_eq!(a.usize_flag("gen", 8), 8);
        assert_eq!(a.u64_flag("seed", 42), 42);
        assert_eq!(a.f64_flag("ratio", 0.4), 0.4);
        assert!(!a.bool_flag("verbose"));
    }

    #[test]
    fn u64_flags_hold_full_width_seeds() {
        let a = parse("loadtest --seed 18446744073709551615");
        assert_eq!(a.u64_flag("seed", 0), u64::MAX);
    }

    #[test]
    fn flag_value_looking_like_negative_number() {
        let a = parse("x --offset -3");
        // '-3' does not start with --, so it is consumed as the value
        assert_eq!(a.str_flag("offset", ""), "-3");
    }

    #[test]
    fn empty() {
        let a = parse("");
        assert!(a.subcommand.is_none());
        assert!(a.positional.is_empty());
    }
}
