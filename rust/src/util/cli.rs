//! Tiny argv parser — in-tree substitute for clap (offline image) — plus
//! the centralized usage text for every `moepim` subcommand.
//!
//! Supports `subcommand --flag value --flag=value --bool-flag positional`.
//! The launcher (`main.rs`) renders help exclusively from [`usage`], so a
//! new flag is documented in exactly one place and `moepim <sub> --help`
//! and the root usage can never drift apart.

use std::collections::BTreeMap;

/// Centralized usage strings: one constant per subcommand plus the root
/// summary, looked up by [`usage::for_subcommand`].
pub mod usage {
    /// Root usage: every subcommand with a one-line description.
    pub const ROOT: &str = "\
moepim — area-efficient PIM for MoE (paper reproduction)

subcommands (moepim <subcommand> --help for flags):
  eval <fig4a|fig4b|fig5|table1|ratio-sweep|calibration|ablation|all>  regenerate paper artefacts
  simulate [flags]      one simulator run
  trace [flags]         inspect a workload trace
  serve [flags]         threaded serving demo (real model)
  generate [flags]      single-sequence generation (real model)
  loadtest [flags]      seeded load experiment -> JSON SloReport v1
                        (virtual clock by default: byte-identical per seed;
                         --real drives the threaded server; --shards N >= 2
                         fans out and emits the merged v2 report;
                         --smoke runs the CI matrix)
  shardtest [flags]     sharded multi-server fan-out -> merged JSON
                        SloReport v2 with per-shard breakdown + imbalance
                        metrics (virtual clusters by default; --real
                        drives N real servers concurrently, each with its
                        own router thread and PJRT client;
                        --bench-cluster writes the concurrency bench)
  calibrate [flags]     fit VirtualConfig cost constants against a
                        recorded moepim.trace.v1 run -> JSON
                        moepim.calibration.v1 with a fit-quality report
  perfcmp OLD NEW       compare two BENCH_*.json perf artifacts leg by
                        leg; exit 3 on regression beyond --threshold
                        (CI's perf-trajectory gate)

common flags: --group-size N --grouping U|S --sched T|C|O --kv --go
              --prompt N --gen N --seed N --routing token|expert --skew X
              --config file.json (simulate; overrides flags)";

    /// `moepim eval` flags.
    pub const EVAL: &str = "\
moepim eval <fig4a|fig4b|fig5|table1|ratio-sweep|calibration|ablation|all>
            [--gen N]";

    /// `moepim simulate` flags.
    pub const SIMULATE: &str = "\
moepim simulate [--group-size N] [--grouping U|S] [--sched T|C|O]
                [--kv] [--go] [--prompt N] [--gen N] [--seed N]
                [--routing token|expert] [--skew X]
                [--config file.json  (overrides flags)]";

    /// `moepim trace` flags.
    pub const TRACE: &str = "\
moepim trace [--tokens N] [--skew X] [--seed N] [--routing token|expert]";

    /// `moepim serve` flags.
    pub const SERVE: &str = "\
moepim serve [--prompts N] [--gen N] [--prefill-chunk N] [--artifacts DIR]
             [--qos] [--priority-mix X]
             [--trace-out FILE] [--metrics-file FILE]

  --prefill-chunk N   chunked prefill: admit prompts into slots at most N
                      tokens per router cycle, interleaved with decode
                      (0 = monolithic prefill, the default); output token
                      streams are bit-identical either way
  --qos               priority-aware admission + decode-side preemption:
                      interactive requests are admitted first and may
                      checkpoint a batch-tier slot (KV + GO banks +
                      sampling cursor) to claim it; preempted requests
                      are requeued and restored bit-exactly later
  --priority-mix X    interactive share in [0,1], strided deterministically
                      over request ids (1.0 = all interactive, the
                      default; ignored without --qos)
  on shutdown the full ServerStats dump is printed (the same pretty-printer
  the shardtest paths use)";

    /// Observability flags shared by `serve`, `loadtest`, and `shardtest`.
    pub const OBS_FLAGS: &str = "\
observability flags:
  --trace-out FILE    dump the request-lifecycle span trace as a Chrome
                      trace-event JSON document (moepim.spans.v1 — load
                      it in Perfetto / chrome://tracing; pid = shard,
                      tid = router thread, counter tracks for queue
                      depths).  Virtual-clock traces are byte-identical
                      per seed; real traces stamp one process-global
                      monotonic clock across all router threads.  Spans
                      are off — and cost nothing on the hot path —
                      without this flag.
  --metrics-file FILE write a Prometheus-style text snapshot of the run's
                      counters, gauges, and latency summaries on
                      shutdown (the same unified registry embedded as
                      the `metrics` section of the SLO reports)";

    /// `moepim generate` flags.
    pub const GENERATE: &str = "\
moepim generate [--prompt-len N] [--gen N] [--artifacts DIR] [--check]";

    /// Traffic-shape flags shared by `loadtest` and `shardtest`.
    pub const WORKLOAD_FLAGS: &str = "\
workload flags:
  --seed N --requests N --process poisson|bursty|closed|replay
  --policy fifo|sjf|edf --rate RPS --on-ms X --off-ms X --users N
  --think-ms X --replay-us T0,T1,... --sizes trace|uniform|fixed
  --prompt N --gen N --skew X --slo-ms X --deadline-slack-us N
  --slots B --layers L --experts E
  --prefill-chunk N   chunked prefill budget (prompt tokens per slot per
                      router cycle; 0 = monolithic admission, the default)
  --qos               priority-aware admission + decode-side preemption
                      (checkpoint/restore of batch-tier slots; off by
                      default — the seed scheduling behaviour)
  --priority-mix X    interactive share in [0,1], strided over request ids
                      (1.0 = single-tier, the default; scenario presets
                      carry their own mix, which this flag overrides)";

    /// `moepim loadtest` flags (v1 report; `--shards` upgrades to v2).
    pub const LOADTEST: &str = "\
moepim loadtest [workload flags] [--shards N] [--placement P]
                [--scenario NAME] [--record FILE] [--replay FILE]
                [--real] [--artifacts DIR] [--out FILE] [--smoke]

  virtual clock by default: reports are byte-identical per seed.
  --real    drive the threaded server instead (wall clock)
  --queue-cap N     (--real) shed submissions that find N requests
            already waiting with an immediate terminal overloaded
            error (0 = unbounded, the default)
  --shards N >= 2   fan out across N backends and emit the merged
            moepim.slo_report.v2 (equivalent to `moepim shardtest`)
  --scenario NAME   run a named scenario preset instead of composing
            workload flags: diurnal | flash-crowd | long-prompt-flood |
            mixed-tenants (each a seeded WorkloadSpec; --seed and
            --requests still apply, other workload flags are ignored)
  --record FILE     dump the served workload as a moepim.trace.v1
            document (arrivals, sizes, deadlines, shard tags, outcomes)
            for replay and calibration
  --replay FILE     replay a recorded moepim.trace.v1 document exactly
            (ns-precision arrivals; overrides workload flags) — a
            virtual-clock replay of a virtual-clock recording
            reproduces its report byte for byte
  --bench-scenarios run every preset on the virtual backend and write
            the BENCH_scenarios.json perf artifact (record-only)
  --bench-qos run the mixed-tenants preset with QoS off and on and
            write the BENCH_qos.json perf artifact (record-only:
            interactive p99 TTFT, batch p99 e2e, preemption counters)
  --smoke   run the CI determinism matrix + real-server legs (incl.
            the 2-shard concurrent-cluster backpressure leg, the
            record->replay->compare leg, the scenario sweep, and the
            mixed-tenant qos preemption leg)";

    /// `moepim calibrate` flags.
    pub const CALIBRATE: &str = "\
moepim calibrate --trace FILE [--out FILE]
                 [--slots B] [--layers L] [--experts E] [--prefill-chunk N]

  fit VirtualConfig's cost constants (cycle_ns, dispatch_overhead_ns,
  prefill_ns_per_token) against a recorded moepim.trace.v1 run by
  least squares over the recorded per-request service times, then
  re-predict the trace with the calibrated config and report p50/p99
  end-to-end error.  Record the trace with `loadtest --record` (use a
  --real run to calibrate the virtual model against the PJRT server).

  --trace FILE   the recorded moepim.trace.v1 document (required)
  --out FILE     write the moepim.calibration.v1 document to FILE
                 (default: print to stdout)
  --max-err-pct X  exit 3 when the re-predicted p50 or p99 end-to-end
                 error exceeds X percent (0 = report only, the default;
                 CI gates real-backend calibration at 15)
  --slots/--layers/--experts/--prefill-chunk  base-config overrides
                 (chip shape is not fitted, only cost constants are)";

    /// `moepim shardtest` flags (merged v2 report).
    pub const SHARDTEST: &str = "\
moepim shardtest [--shards N] [--placement P] [--virtual | --real]
                 [--serial] [--shed-depth N] [--intake-cap N]
                 [--queue-cap N] [--bench-cluster] [--bench-placement]
                 [workload flags] [--artifacts DIR] [--out FILE]

  --shards N      number of backends to fan out across (default 2)
  --placement P   round-robin | least-outstanding | size-hash |
                  route-aware | live | dynamic
                  (route-aware shards by the expert group of each request's
                   seeded routing stream — exact for virtual backends, a
                   seeded proxy under --real; live places each arrival
                   online by live in-flight counts instead of split-time
                   estimates — a concurrent Cluster front door under
                   --real, lock-step virtual backends otherwise, and it
                   requires an open-loop arrival process; dynamic is the
                   full placement control loop — capacity-weighted routing
                   plus periodic queued-request migration and area-ledgered
                   hot-expert-group replication, open-loop only)
  --rebalance-every N   (dynamic) run a rebalance pass every N arrivals
                  (default 16; 0 disables migration)
  --replicate-budget-mm2 X  (dynamic, virtual) area budget the replica
                  ledger may spend on hot-group replicas (default 0 =
                  replication off; each replica is priced at the paper
                  chip's per-group macro area)
  --shard-slots A,B,..  (dynamic, virtual) per-shard slot counts for a
                  heterogeneous fleet (one entry per shard; other config
                  fields are shared)
  --virtual       N virtual clusters (default; byte-identical per seed)
  --real          N real servers running concurrently, each with its own
                  engine and PJRT client on its own router thread; the
                  fan-out's wall time is the slowest shard's, not the sum
  --serial        (--real) legacy one-shard-at-a-time fan-out, kept as
                  the A/B baseline for the concurrency bench
  --shed-depth N  (--real --placement live) shed arrivals once every
                  backend holds slots+N in-flight requests; shed requests
                  get an immediate terminal overloaded reply and count in
                  shed_requests (0 = never shed, the default)
  --intake-cap N  (--real --placement live) bound the front-door intake
                  queue; submitters block while it is full (0 = 1024)
  --queue-cap N   (--real) per-backend admission-queue shedding cap
                  (0 = unbounded, the default)
  --bench-cluster run the single/serial/concurrent perf comparison and
                  write BENCH_cluster.json (--out overrides the path)
  --bench-placement  run the static-route-aware / dynamic /
                  dynamic-replicate comparison over a skewed flash crowd
                  and write BENCH_placement.json (--out overrides)
  --out FILE      also write the merged v2 report to FILE

  note: closed-loop specs split their user population across shards with
  a floor of one user per request-holding shard, so keep --users >= N
  when the concurrency level itself is under study";

    /// `moepim perfcmp` flags.
    pub const PERFCMP: &str = "\
moepim perfcmp OLD.json NEW.json [--threshold PCT]

  compare two bench artifacts of the same schema (BENCH_scenarios.json
  or BENCH_cluster.json) leg by leg: tokens_per_s (higher is better)
  and p50/p99 end-to-end latency (lower is better).  Legs present in
  only one artifact are skipped — a new scenario is not a regression.
  CI runs this between the committed baseline and the freshly benched
  artifact.

  --threshold PCT   regression threshold in percent (default 10)

  exit codes: 0 = no regression, 3 = at least one shared metric
  regressed beyond the threshold, 1/2 = unreadable or incomparable
  input";

    /// The usage text for `name`, if it is a known subcommand.
    pub fn for_subcommand(name: &str) -> Option<&'static str> {
        match name {
            "eval" => Some(EVAL),
            "simulate" => Some(SIMULATE),
            "trace" => Some(TRACE),
            "serve" => Some(SERVE),
            "generate" => Some(GENERATE),
            "loadtest" => Some(LOADTEST),
            "shardtest" => Some(SHARDTEST),
            "calibrate" => Some(CALIBRATE),
            "perfcmp" => Some(PERFCMP),
            _ => None,
        }
    }

    /// Full help text for `name`: the subcommand usage, with the shared
    /// workload-flag and observability-flag blocks appended where they
    /// apply (so those flags are documented exactly once).
    pub fn help_for(name: &str) -> Option<String> {
        for_subcommand(name).map(|u| match name {
            "loadtest" | "shardtest" => {
                format!("{u}\n\n{WORKLOAD_FLAGS}\n\n{OBS_FLAGS}")
            }
            "serve" => format!("{u}\n\n{OBS_FLAGS}"),
            _ => u.to_string(),
        })
    }
}

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse `argv[1..]`.  The first non-flag token becomes the subcommand;
    /// later non-flag tokens are positional.  `--flag value` consumes the
    /// next token unless it starts with `--`; bare `--flag` stores "true".
    ///
    /// Ambiguity note: `--bool positional` reads the positional as the
    /// flag's value — write boolean flags last or as `--flag=true`.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let tokens: Vec<String> = argv.into_iter().collect();
        let mut args = Args::default();
        let mut i = 0;
        while i < tokens.len() {
            let tok = &tokens[i];
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if i + 1 < tokens.len()
                    && !tokens[i + 1].starts_with("--")
                {
                    args.flags
                        .insert(stripped.to_string(), tokens[i + 1].clone());
                    i += 1;
                } else {
                    args.flags.insert(stripped.to_string(), "true".into());
                }
            } else if args.subcommand.is_none() {
                args.subcommand = Some(tok.clone());
            } else {
                args.positional.push(tok.clone());
            }
            i += 1;
        }
        args
    }

    pub fn str_flag(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.into())
    }

    pub fn usize_flag(&self, key: &str, default: usize) -> usize {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn u64_flag(&self, key: &str, default: u64) -> u64 {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn f64_flag(&self, key: &str, default: f64) -> f64 {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn bool_flag(&self, key: &str) -> bool {
        matches!(self.flags.get(key).map(|s| s.as_str()), Some("true") | Some("1"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|t| t.to_string()))
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("simulate x --group-size 4 --sched=resched --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("simulate"));
        assert_eq!(a.usize_flag("group-size", 2), 4);
        assert_eq!(a.str_flag("sched", ""), "resched");
        assert!(a.bool_flag("verbose"));
        assert_eq!(a.positional, vec!["x"]);
    }

    #[test]
    fn defaults() {
        let a = parse("eval");
        assert_eq!(a.usize_flag("gen", 8), 8);
        assert_eq!(a.u64_flag("seed", 42), 42);
        assert_eq!(a.f64_flag("ratio", 0.4), 0.4);
        assert!(!a.bool_flag("verbose"));
    }

    #[test]
    fn u64_flags_hold_full_width_seeds() {
        let a = parse("loadtest --seed 18446744073709551615");
        assert_eq!(a.u64_flag("seed", 0), u64::MAX);
    }

    #[test]
    fn flag_value_looking_like_negative_number() {
        let a = parse("x --offset -3");
        // '-3' does not start with --, so it is consumed as the value
        assert_eq!(a.str_flag("offset", ""), "-3");
    }

    #[test]
    fn empty() {
        let a = parse("");
        assert!(a.subcommand.is_none());
        assert!(a.positional.is_empty());
    }

    #[test]
    fn usage_covers_every_subcommand() {
        for sub in [
            "eval", "simulate", "trace", "serve", "generate", "loadtest",
            "shardtest", "calibrate", "perfcmp",
        ] {
            assert!(usage::ROOT.contains(sub), "root usage misses {sub}");
            assert!(
                usage::for_subcommand(sub).is_some(),
                "no usage text for {sub}"
            );
        }
        assert_eq!(usage::for_subcommand("lifo"), None);
    }

    #[test]
    fn usage_documents_the_sharding_surface() {
        assert!(usage::LOADTEST.contains("--shards"));
        assert!(usage::SHARDTEST.contains("--shards"));
        assert!(usage::SHARDTEST.contains("--placement"));
        assert!(usage::SHARDTEST.contains("route-aware"));
        // the concurrent-cluster surface: live placement, backpressure
        // knobs, the serial A/B baseline, and the perf bench
        assert!(usage::SHARDTEST.contains("live"));
        assert!(usage::SHARDTEST.contains("--serial"));
        assert!(usage::SHARDTEST.contains("--shed-depth"));
        assert!(usage::SHARDTEST.contains("--intake-cap"));
        assert!(usage::SHARDTEST.contains("--queue-cap"));
        assert!(usage::SHARDTEST.contains("--bench-cluster"));
        assert!(usage::SHARDTEST.contains("concurrently"));
        // the placement control loop: dynamic mode, its knobs, and the
        // heterogeneous-fleet override plus the perf bench
        assert!(usage::SHARDTEST.contains("dynamic"));
        assert!(usage::SHARDTEST.contains("--rebalance-every"));
        assert!(usage::SHARDTEST.contains("--replicate-budget-mm2"));
        assert!(usage::SHARDTEST.contains("--shard-slots"));
        assert!(usage::SHARDTEST.contains("--bench-placement"));
        assert!(usage::LOADTEST.contains("--queue-cap"));
        // no doc may claim real shards run serially by necessity
        assert!(!usage::ROOT.contains("single-owner"));
        assert!(!usage::SHARDTEST.contains("single-owner"));
        // the shared workload flags ride along on both help texts
        for sub in ["loadtest", "shardtest"] {
            let help = usage::help_for(sub).expect("known subcommand");
            assert!(help.contains("--policy fifo|sjf|edf"), "{sub}");
            assert!(help.contains("--process poisson|bursty|closed|replay"),
                    "{sub}");
        }
    }

    #[test]
    fn usage_documents_the_trace_lifecycle() {
        // record → replay → calibrate → scenarios: every stage of the
        // lifecycle is discoverable from the usage text
        assert!(usage::LOADTEST.contains("--scenario"));
        assert!(usage::LOADTEST.contains("--record"));
        assert!(usage::LOADTEST.contains("--replay"));
        assert!(usage::LOADTEST.contains("moepim.trace.v1"));
        for name in
            ["diurnal", "flash-crowd", "long-prompt-flood", "mixed-tenants"]
        {
            assert!(usage::LOADTEST.contains(name), "preset {name} undocumented");
        }
        assert!(usage::ROOT.contains("calibrate"));
        assert!(usage::CALIBRATE.contains("--trace"));
        assert!(usage::CALIBRATE.contains("moepim.calibration.v1"));
        assert!(usage::CALIBRATE.contains("cycle_ns"));
        assert_eq!(usage::for_subcommand("calibrate"), Some(usage::CALIBRATE));
    }

    #[test]
    fn usage_documents_the_observability_surface() {
        // --trace-out / --metrics-file ride the shared block on every
        // subcommand that spawns a traced run; perfcmp documents its
        // regression exit code
        for sub in ["serve", "loadtest", "shardtest"] {
            let help = usage::help_for(sub).expect("known subcommand");
            assert!(help.contains("--trace-out"), "{sub}");
            assert!(help.contains("--metrics-file"), "{sub}");
        }
        assert!(usage::OBS_FLAGS.contains("moepim.spans.v1"));
        assert!(usage::OBS_FLAGS.contains("byte-identical"));
        assert!(usage::PERFCMP.contains("--threshold"));
        assert!(usage::PERFCMP.contains("exit codes"));
        assert!(usage::ROOT.contains("perfcmp"));
        assert_eq!(usage::for_subcommand("perfcmp"), Some(usage::PERFCMP));
    }

    #[test]
    fn usage_documents_the_qos_surface() {
        // serve takes --qos/--priority-mix directly; loadtest/shardtest
        // get them via the shared workload-flag block
        assert!(usage::SERVE.contains("--qos"));
        assert!(usage::SERVE.contains("--priority-mix"));
        for sub in ["loadtest", "shardtest"] {
            let help = usage::help_for(sub).expect("known subcommand");
            assert!(help.contains("--qos"), "{sub}");
            assert!(help.contains("--priority-mix"), "{sub}");
        }
        // the preemption mechanism and its bench/smoke legs are named
        assert!(usage::SERVE.contains("checkpoint"));
        assert!(usage::LOADTEST.contains("--bench-qos"));
        assert!(usage::LOADTEST.contains("BENCH_qos.json"));
        assert!(usage::LOADTEST.contains("qos preemption leg"));
    }

    #[test]
    fn usage_documents_chunked_prefill_everywhere_it_applies() {
        // serve takes the flag directly; loadtest/shardtest get it via the
        // shared workload-flag block (documented exactly once)
        assert!(usage::SERVE.contains("--prefill-chunk"));
        for sub in ["loadtest", "shardtest"] {
            let help = usage::help_for(sub).expect("known subcommand");
            assert!(help.contains("--prefill-chunk"), "{sub}");
        }
    }
}
