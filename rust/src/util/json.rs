//! Minimal JSON codec — in-tree substitute for serde_json (offline image).
//!
//! Covers exactly what this crate needs: parsing `artifacts/manifest.json`
//! and experiment/config files, and serialising evaluation reports.  Not a
//! general-purpose library: numbers are f64 (with i64 fast-path accessors),
//! strings support the standard escapes, and parse errors carry byte
//! offsets.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) so serialisation is
/// deterministic — important for golden-file tests.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ----- accessors -------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style path access.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|v| v as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|v| {
            if v >= 0.0 && v.fract() == 0.0 {
                Some(v as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // ----- builders --------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(v: f64) -> Json {
        Json::Num(v)
    }

    pub fn str(v: &str) -> Json {
        Json::Str(v.to_string())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    // ----- serialisation ---------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => {
                if v.fract() == 0.0 && v.abs() < 9e15 {
                    out.push_str(&format!("{}", *v as i64));
                } else {
                    out.push_str(&format!("{v}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    item.write(out, indent + 1, pretty);
                }
                if !items.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push_str(if pretty { ": " } else { ":" });
                    v.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------------

pub fn parse(src: &str) -> Result<Json, JsonError> {
    let mut p = Parser { src: src.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.src.len() {
        return Err(p.err("trailing content"));
    }
    Ok(v)
}

struct Parser<'a> {
    src: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.pos, message: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.src.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while let Some(b) = self.peek() {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.src[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self
                                .bump()
                                .and_then(|b| (b as char).to_digit(16))
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            code = code * 16 + d;
                        }
                        s.push(
                            char::from_u32(code)
                                .ok_or_else(|| self.err("bad codepoint"))?,
                        );
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(b) if b < 0x80 => s.push(b as char),
                Some(b) => {
                    // multi-byte UTF-8: copy raw bytes through
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    self.pos = start + len;
                    let chunk = self
                        .src
                        .get(start..start + len)
                        .ok_or_else(|| self.err("truncated utf8"))?;
                    s.push_str(
                        std::str::from_utf8(chunk)
                            .map_err(|_| self.err("invalid utf8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.src[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": false}"#).unwrap();
        assert_eq!(v.path(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.path(&["a"]).unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("c")
        );
        assert_eq!(v.get("d").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn roundtrip() {
        let v = Json::obj(vec![
            ("num", Json::num(42.0)),
            ("frac", Json::num(1.25)),
            ("arr", Json::arr([Json::num(1.0), Json::str("x")])),
            ("s", Json::str("he\"llo\n")),
        ]);
        let text = v.to_string_pretty();
        assert_eq!(parse(&text).unwrap(), v);
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Json::num(1536.0).to_string_pretty(), "1536");
    }

    #[test]
    fn parses_real_manifest() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let m = parse(&text).unwrap();
            assert!(m.path(&["model", "d_model"]).unwrap().as_usize().is_some());
        }
    }

    #[test]
    fn error_offsets() {
        let e = parse("{\"a\": }").unwrap_err();
        assert!(e.offset >= 6, "{e}");
        assert!(parse("[1, 2").is_err());
        assert!(parse("[1] junk").is_err());
    }

    #[test]
    fn unicode_string() {
        let v = parse("\"caf\u{e9} \\u00e9\"").unwrap();
        assert_eq!(v.as_str(), Some("café é"));
    }
}
