//! Micro property-testing harness — in-tree substitute for proptest
//! (offline image).
//!
//! A property test draws `cases` random inputs from a seeded [`Pcg32`] and
//! asserts an invariant on each.  On failure it retries the same case with
//! progressively "smaller" regenerations (halving size hints) to report a
//! small counterexample, then panics with the seed so the case replays
//! deterministically:
//!
//! ```ignore
//! prop::check(200, |g| {
//!     let t = g.size(1, 64);
//!     let xs = g.vec_f64(t);
//!     assert!(my_invariant(&xs));
//! });
//! ```

use super::rng::Pcg32;

/// Case generator handed to property closures.
pub struct Gen {
    pub rng: Pcg32,
    pub case_seed: u64,
    /// shrink factor in (0, 1]; sizes scale down with it during shrinking
    scale: f64,
}

impl Gen {
    /// A size in `[lo, hi]`, scaled down while shrinking.
    pub fn size(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        let span = hi - lo;
        let scaled = ((span as f64 * self.scale).round() as usize).min(span);
        lo + if scaled == 0 { 0 } else { self.rng.gen_range(scaled + 1) }
    }

    pub fn usize(&mut self, bound: usize) -> usize {
        self.rng.gen_range(bound)
    }

    pub fn f64(&mut self) -> f64 {
        self.rng.gen_f64()
    }

    pub fn normal(&mut self) -> f64 {
        self.rng.gen_normal()
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.gen_f64() < p
    }

    pub fn vec_f64(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.rng.gen_normal()).collect()
    }
}

/// Run `cases` random cases of `prop`.  Panics (with replay seed) on the
/// first failing case, after attempting 8 shrink rounds.
pub fn check<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(cases: u64, prop: F) {
    check_seeded(0xC0DE_BA5E, cases, prop)
}

pub fn check_seeded<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(
    base_seed: u64,
    cases: u64,
    prop: F,
) {
    for case in 0..cases {
        let case_seed = base_seed ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        if run_case(&prop, case_seed, 1.0).is_err() {
            // Shrink: replay with smaller size hints; keep the smallest
            // failing scale.
            let mut failing_scale = 1.0;
            for k in 1..=8 {
                let scale = 1.0 / (1 << k) as f64;
                if run_case(&prop, case_seed, scale).is_err() {
                    failing_scale = scale;
                } else {
                    break;
                }
            }
            // Re-run unprotected so the original assertion surfaces, with
            // the replay info attached via a wrapping message.
            eprintln!(
                "property failed: seed={base_seed:#x} case={case} \
                 (replay scale {failing_scale})"
            );
            let mut g = Gen {
                rng: Pcg32::new(case_seed),
                case_seed,
                scale: failing_scale,
            };
            prop(&mut g);
            unreachable!("case passed on unprotected replay");
        }
    }
}

fn run_case<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(
    prop: &F,
    case_seed: u64,
    scale: f64,
) -> Result<(), ()> {
    let result = std::panic::catch_unwind(|| {
        let mut g =
            Gen { rng: Pcg32::new(case_seed), case_seed, scale };
        prop(&mut g);
    });
    result.map_err(|_| ())
}

/// Suppress the default panic backtraces while probing cases (the final
/// replay still prints normally).  Call at the start of a test if the
/// shrink probing is too noisy; optional.
pub fn quiet_probe<T>(f: impl FnOnce() -> T) -> T {
    let prev = std::panic::take_hook();
    std::panic::set_hook(Box::new(|_| {}));
    let out = f();
    std::panic::set_hook(prev);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        check(50, |g| {
            let n = g.size(0, 32);
            let v = g.vec_f64(n);
            assert_eq!(v.len(), n);
        });
    }

    #[test]
    fn deterministic_replay() {
        let mut firsts = Vec::new();
        for _ in 0..2 {
            let mut g = Gen {
                rng: Pcg32::new(1234),
                case_seed: 1234,
                scale: 1.0,
            };
            firsts.push(g.usize(1000));
        }
        assert_eq!(firsts[0], firsts[1]);
    }

    #[test]
    #[should_panic]
    fn failing_property_panics() {
        quiet_probe(|| {
            check(50, |g| {
                let n = g.size(0, 100);
                assert!(n < 10, "found large n = {n}");
            });
        });
    }

    #[test]
    fn sizes_respect_bounds() {
        check(100, |g| {
            let n = g.size(3, 7);
            assert!((3..=7).contains(&n));
        });
    }
}
