//! Deterministic PRNG (PCG32 + SplitMix64 seeding) — in-tree substitute for
//! the `rand` crate (offline image).  Everything that samples (trace
//! generation, uniform grouping, property tests) goes through this so runs
//! are reproducible from a single `u64` seed.

/// One SplitMix64 step: advance `state` by the golden-ratio increment and
/// return the mixed output.  Shared by [`Pcg32::new`] seeding and the
/// workload shard driver's stateless size hash, so the magic constants
/// live in exactly one place.
pub(crate) fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// PCG32 (Melissa O'Neill's `pcg32_random_r`): small, fast, statistically
/// solid for simulation workloads.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    /// Seed via SplitMix64 so nearby seeds give uncorrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut rng = Pcg32 { state: 0, inc: splitmix64(&mut sm) | 1 };
        rng.state = splitmix64(&mut sm);
        rng.next_u32();
        rng
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6364136223846793005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, bound)` (Lemire-ish rejection via modulo of a wide
    /// product; bias < 2^-32, fine for simulation).
    pub fn gen_range(&mut self, bound: usize) -> usize {
        assert!(bound > 0, "gen_range bound must be positive");
        ((self.next_u32() as u64 * bound as u64) >> 32) as usize
    }

    /// Uniform f64 in [0, 1).
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box-Muller.
    pub fn gen_normal(&mut self) -> f64 {
        let u1 = self.gen_f64().max(1e-300);
        let u2 = self.gen_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i + 1);
            xs.swap(i, j);
        }
    }

    /// Zipf-distributed index in `[0, n)` with exponent `s` (inverse-CDF on
    /// precomputed weights is overkill here; n is small — #experts).
    pub fn gen_zipf(&mut self, n: usize, s: f64) -> usize {
        debug_assert!(n > 0);
        let total: f64 = (1..=n).map(|k| (k as f64).powf(-s)).sum();
        let mut u = self.gen_f64() * total;
        for k in 1..=n {
            u -= (k as f64).powf(-s);
            if u <= 0.0 {
                return k - 1;
            }
        }
        n - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg32::new(42);
        let mut b = Pcg32::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg32::new(1);
        let mut b = Pcg32::new(2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn range_bounds() {
        let mut r = Pcg32::new(7);
        for _ in 0..10_000 {
            assert!(r.gen_range(16) < 16);
        }
        // all values hit
        let mut seen = [false; 16];
        for _ in 0..10_000 {
            seen[r.gen_range(16)] = true;
        }
        assert!(seen.iter().all(|&x| x));
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Pcg32::new(9);
        let mut acc = 0.0;
        for _ in 0..10_000 {
            let v = r.gen_f64();
            assert!((0.0..1.0).contains(&v));
            acc += v;
        }
        let mean = acc / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gen_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.08, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::new(13);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn zipf_skews_low() {
        let mut r = Pcg32::new(17);
        let mut counts = [0usize; 8];
        for _ in 0..20_000 {
            counts[r.gen_zipf(8, 1.1)] += 1;
        }
        assert!(counts[0] > counts[3], "{counts:?}");
        assert!(counts[0] > counts[7] * 3, "{counts:?}");
    }
}
