//! Small in-tree substitutes for crates unavailable in this offline image
//! (serde/serde_json, rand, clap, criterion, proptest — see Cargo.toml note),
//! plus shared formatting helpers.

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;

/// Format a nanosecond quantity with thousands separators (paper tables
/// print e.g. `2,297,724`).
pub fn fmt_thousands(v: u64) -> String {
    let s = v.to_string();
    let mut out = String::with_capacity(s.len() + s.len() / 3);
    let bytes = s.as_bytes();
    for (i, b) in bytes.iter().enumerate() {
        if i > 0 && (bytes.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(*b as char);
    }
    out
}

/// Format a float to `prec` significant-looking decimals without trailing
/// zeros noise (for report tables).
pub fn fmt_f(v: f64, prec: usize) -> String {
    format!("{v:.prec$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thousands() {
        assert_eq!(fmt_thousands(0), "0");
        assert_eq!(fmt_thousands(999), "999");
        assert_eq!(fmt_thousands(1000), "1,000");
        assert_eq!(fmt_thousands(2297724), "2,297,724");
        assert_eq!(fmt_thousands(5393776), "5,393,776");
    }

    #[test]
    fn floats() {
        assert_eq!(fmt_f(15.61234, 1), "15.6");
    }
}
