//! Reader for `artifacts/manifest.json`, the contract between the python
//! AOT path and the rust runtime: functional-model dims plus the artifact
//! table (file names and input specs).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::{self, Json};

/// Input spec of one HLO executable parameter.
#[derive(Debug, Clone, PartialEq)]
pub struct InputSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// One AOT-compiled executable.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<InputSpec>,
}

/// Functional model dims as lowered (mirror of python's ModelConfig).
#[derive(Debug, Clone, PartialEq)]
pub struct FunctionalModel {
    pub d_model: usize,
    pub n_experts: usize,
    pub top_k: usize,
    pub d_ff: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub vocab: usize,
    pub prompt_len: usize,
    pub max_seq: usize,
    pub expert_capacity: usize,
    /// serving batch width B of the slot-batched decode artifacts
    pub batch_slots: usize,
    /// functional stack depth L (`n_layers_functional` in the manifest)
    pub n_layers: usize,
    /// GO-bank capacity per layer (len == `n_layers`; uniform today, but
    /// the schema supports heterogeneous depth-wise capacities)
    pub expert_capacity_per_layer: Vec<usize>,
}

impl FunctionalModel {
    /// Expert capacity of `layer`'s GO bank.
    pub fn capacity(&self, layer: usize) -> usize {
        self.expert_capacity_per_layer[layer]
    }
}

/// Artifact name of a per-block family member at `layer`: layer 0 keeps
/// the bare name (an L=1 artifact set is byte-identical to the
/// pre-multi-layer one), deeper layers append `_l{layer}` — the naming
/// contract with python's `compile.aot.layer_artifact`.
pub fn layer_artifact(base: &str, layer: usize) -> String {
    if layer == 0 {
        base.to_string()
    } else {
        format!("{base}_l{layer}")
    }
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: FunctionalModel,
    pub artifacts: BTreeMap<String, ArtifactEntry>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(dir, &text)
    }

    /// Default location: `$MOEPIM_ARTIFACTS` or `<crate root>/artifacts`.
    pub fn load_default() -> Result<Manifest> {
        let dir = std::env::var("MOEPIM_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| {
                Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
            });
        Self::load(&dir)
    }

    pub fn parse(dir: &Path, text: &str) -> Result<Manifest> {
        let v = json::parse(text).map_err(|e| anyhow!("{e}"))?;
        let format = v
            .get("format")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("manifest missing 'format'"))?;
        if format != "hlo-text/return-tuple" {
            return Err(anyhow!("unsupported artifact format '{format}'"));
        }

        let m = v.get("model").ok_or_else(|| anyhow!("missing 'model'"))?;
        let field = |k: &str| -> Result<usize> {
            m.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("manifest model missing '{k}'"))
        };
        let n_layers = field("n_layers_functional")?;
        if n_layers == 0 {
            return Err(anyhow!("manifest n_layers_functional must be >= 1"));
        }
        let caps = m
            .get("expert_capacity_per_layer")
            .and_then(Json::as_arr)
            .ok_or_else(|| {
                anyhow!("manifest model missing 'expert_capacity_per_layer'")
            })?
            .iter()
            .map(|c| {
                c.as_usize()
                    .ok_or_else(|| anyhow!("bad expert_capacity_per_layer"))
            })
            .collect::<Result<Vec<_>>>()?;
        if caps.len() != n_layers {
            return Err(anyhow!(
                "expert_capacity_per_layer has {} entries for {} layers",
                caps.len(),
                n_layers
            ));
        }
        let model = FunctionalModel {
            d_model: field("d_model")?,
            n_experts: field("n_experts")?,
            top_k: field("top_k")?,
            d_ff: field("d_ff")?,
            n_heads: field("n_heads")?,
            d_head: field("d_head")?,
            vocab: field("vocab")?,
            prompt_len: field("prompt_len")?,
            max_seq: field("max_seq")?,
            expert_capacity: field("expert_capacity")?,
            batch_slots: field("batch_slots")?,
            n_layers,
            expert_capacity_per_layer: caps,
        };

        let mut artifacts = BTreeMap::new();
        let arts = v
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("missing 'artifacts'"))?;
        for (name, entry) in arts {
            let file = entry
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("artifact {name} missing 'file'"))?;
            let mut inputs = Vec::new();
            for inp in entry
                .get("inputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("artifact {name} missing 'inputs'"))?
            {
                let shape = inp
                    .get("shape")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("artifact {name} bad shape"))?
                    .iter()
                    .map(|d| {
                        d.as_usize()
                            .ok_or_else(|| anyhow!("bad dim in {name}"))
                    })
                    .collect::<Result<Vec<_>>>()?;
                let dtype = inp
                    .get("dtype")
                    .and_then(Json::as_str)
                    .unwrap_or("float32")
                    .to_string();
                inputs.push(InputSpec { shape, dtype });
            }
            artifacts.insert(
                name.clone(),
                ArtifactEntry {
                    name: name.clone(),
                    file: dir.join(file),
                    inputs,
                },
            );
        }

        let got: Vec<&str> =
            artifacts.keys().map(String::as_str).collect();
        for required in REQUIRED_ARTIFACTS {
            if !got.contains(required) {
                return Err(anyhow!(
                    "manifest missing required artifact '{required}' \
                     (have: {got:?}) — re-run `make artifacts`"
                ));
            }
        }
        // depth-L sets additionally carry every per-block family at every
        // layer (layer 0 is the bare name, covered above)
        for layer in 1..model.n_layers {
            for family in LAYERED_ARTIFACTS {
                let name = layer_artifact(family, layer);
                if !artifacts.contains_key(&name) {
                    return Err(anyhow!(
                        "manifest says {} layers but is missing '{name}' \
                         — re-run `make artifacts`",
                        model.n_layers
                    ));
                }
            }
        }
        // each layer's declared capacity must match what its sparse-MoE
        // artifact was actually lowered with (the expert-index input is
        // `idx[K]`); a hand-edited capacity list would otherwise only
        // fail at dispatch time (unit-test fixtures may omit input specs)
        for layer in 0..model.n_layers {
            let name = layer_artifact("moe_one_sparse", layer);
            if let Some(idx_spec) = artifacts
                .get(&name)
                .and_then(|entry| entry.inputs.get(1))
            {
                let cap = model.expert_capacity_per_layer[layer];
                if idx_spec.shape != [cap] {
                    return Err(anyhow!(
                        "'{name}' was lowered with expert-index shape \
                         {:?} but the manifest declares capacity {cap} \
                         for layer {layer} — re-run `make artifacts`",
                        idx_spec.shape
                    ));
                }
            }
        }

        Ok(Manifest { dir: dir.to_path_buf(), model, artifacts })
    }

    pub fn entry(&self, name: &str) -> Result<&ArtifactEntry> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("no artifact named '{name}'"))
    }
}

/// Executables the coordinator requires at any depth (aot.py writes
/// exactly these for layer 0, plus `_l{n}` variants of the per-block
/// families below for layers >= 1).
pub const REQUIRED_ARTIFACTS: &[&str] = &[
    "embed_prefill",
    "embed_one",
    "attn_prefill",
    "attn_decode",
    "gate_full",
    "gate_one",
    "moe_full",
    "moe_one",
    "moe_one_sparse",
    "logits_one",
    // slot-batched decode (serving engine)
    "embed_batch",
    "attn_decode_batch",
    "gate_batch",
    "moe_batch_sparse",
];

/// Per-block families lowered once per functional layer (everything
/// except the shared embed_* / logits_one entries).
pub const LAYERED_ARTIFACTS: &[&str] = &[
    "attn_prefill",
    "attn_decode",
    "gate_full",
    "gate_one",
    "moe_full",
    "moe_one",
    "moe_one_sparse",
    "attn_decode_batch",
    "gate_batch",
    "moe_batch_sparse",
];

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(format: &str) -> String {
        format!(
            r#"{{
  "format": "{format}",
  "model": {{"d_model": 256, "n_experts": 16, "top_k": 4, "d_ff": 128,
             "n_heads": 4, "d_head": 64, "vocab": 512, "prompt_len": 32,
             "max_seq": 96, "expert_capacity": 8, "batch_slots": 4,
             "n_layers_functional": 1, "expert_capacity_per_layer": [8],
             "seed": 1, "xbar_rows": 128, "xbar_cols": 128, "adc_bits": 8,
             "dac_bits": 8, "adc_range_factor": 16.0}},
  "artifacts": {{
    "embed_prefill": {{"file": "embed_prefill.hlo.txt",
                       "inputs": [{{"shape": [96], "dtype": "int32"}}]}},
    "embed_one": {{"file": "embed_one.hlo.txt",
                   "inputs": [{{"shape": [1], "dtype": "int32"}}]}},
    "attn_prefill": {{"file": "a.hlo.txt", "inputs": [
        {{"shape": [96, 256], "dtype": "float32"}},
        {{"shape": [1], "dtype": "int32"}}]}},
    "attn_decode": {{"file": "b.hlo.txt", "inputs": []}},
    "gate_full": {{"file": "c.hlo.txt", "inputs": []}},
    "gate_one": {{"file": "d.hlo.txt", "inputs": []}},
    "moe_full": {{"file": "e.hlo.txt", "inputs": []}},
    "moe_one": {{"file": "f.hlo.txt", "inputs": []}},
    "moe_one_sparse": {{"file": "fs.hlo.txt", "inputs": []}},
    "logits_one": {{"file": "g.hlo.txt", "inputs": []}},
    "embed_batch": {{"file": "eb.hlo.txt",
                     "inputs": [{{"shape": [4], "dtype": "int32"}}]}},
    "attn_decode_batch": {{"file": "adb.hlo.txt", "inputs": []}},
    "gate_batch": {{"file": "gb.hlo.txt", "inputs": []}},
    "moe_batch_sparse": {{"file": "mbs.hlo.txt", "inputs": []}}
  }}
}}"#
        )
    }

    /// Rewrite the L=1 sample into a depth-2 one (layered `_l1` entries
    /// for every per-block family).
    fn sample_l2() -> String {
        let mut extra = String::new();
        for family in LAYERED_ARTIFACTS {
            extra.push_str(&format!(
                ",\n    \"{family}_l1\": {{\"file\": \"{family}_l1.hlo.txt\", \
                 \"inputs\": []}}"
            ));
        }
        sample("hlo-text/return-tuple")
            .replace("\"n_layers_functional\": 1", "\"n_layers_functional\": 2")
            .replace(
                "\"expert_capacity_per_layer\": [8]",
                "\"expert_capacity_per_layer\": [8, 8]",
            )
            .replace(
                "\"inputs\": []}\n  }",
                &format!("\"inputs\": []}}{extra}\n  }}"),
            )
    }

    #[test]
    fn parses_sample() {
        let m =
            Manifest::parse(Path::new("/tmp/a"), &sample("hlo-text/return-tuple"))
                .unwrap();
        assert_eq!(m.model.d_model, 256);
        assert_eq!(m.model.expert_capacity, 8);
        assert_eq!(m.model.batch_slots, 4);
        assert_eq!(m.model.n_layers, 1);
        assert_eq!(m.model.expert_capacity_per_layer, vec![8]);
        assert_eq!(m.model.capacity(0), 8);
        let e = m.entry("attn_prefill").unwrap();
        assert_eq!(e.inputs.len(), 2);
        assert_eq!(e.inputs[0].shape, vec![96, 256]);
        assert_eq!(e.inputs[1].dtype, "int32");
        assert!(e.file.ends_with("a.hlo.txt"));
    }

    #[test]
    fn layer_artifact_naming() {
        assert_eq!(layer_artifact("gate_one", 0), "gate_one");
        assert_eq!(layer_artifact("gate_one", 2), "gate_one_l2");
    }

    #[test]
    fn parses_layered_sample() {
        let m = Manifest::parse(Path::new("/tmp/a"), &sample_l2()).unwrap();
        assert_eq!(m.model.n_layers, 2);
        assert_eq!(m.model.expert_capacity_per_layer, vec![8, 8]);
        assert_eq!(m.model.capacity(1), 8);
        assert!(m.entry("gate_one_l1").is_ok());
        assert!(m.entry(&layer_artifact("moe_batch_sparse", 1)).is_ok());
    }

    #[test]
    fn rejects_capacity_artifact_shape_mismatch() {
        // the sparse-MoE artifact was lowered with idx[4] but the model
        // declares capacity 8 for that layer — a hand-edited manifest
        // must fail at parse, not at dispatch
        let text = sample("hlo-text/return-tuple").replace(
            "\"moe_one_sparse\": {\"file\": \"fs.hlo.txt\", \"inputs\": []}",
            "\"moe_one_sparse\": {\"file\": \"fs.hlo.txt\", \"inputs\": [\
               {\"shape\": [1, 256], \"dtype\": \"float32\"},\
               {\"shape\": [4], \"dtype\": \"int32\"},\
               {\"shape\": [4], \"dtype\": \"float32\"}]}",
        );
        let err = Manifest::parse(Path::new("/tmp/a"), &text).unwrap_err();
        assert!(err.to_string().contains("capacity"), "{err}");
    }

    #[test]
    fn rejects_depth_without_layer_artifacts() {
        // claims 2 layers but carries only the layer-0 set
        let text = sample("hlo-text/return-tuple")
            .replace("\"n_layers_functional\": 1", "\"n_layers_functional\": 2")
            .replace(
                "\"expert_capacity_per_layer\": [8]",
                "\"expert_capacity_per_layer\": [8, 8]",
            );
        let err = Manifest::parse(Path::new("/tmp/a"), &text).unwrap_err();
        assert!(err.to_string().contains("_l1"), "{err}");
    }

    #[test]
    fn rejects_capacity_list_depth_mismatch() {
        let text = sample_l2().replace(
            "\"expert_capacity_per_layer\": [8, 8]",
            "\"expert_capacity_per_layer\": [8]",
        );
        let err = Manifest::parse(Path::new("/tmp/a"), &text).unwrap_err();
        assert!(
            err.to_string().contains("expert_capacity_per_layer"),
            "{err}"
        );
    }

    #[test]
    fn rejects_missing_batch_slots() {
        // a pre-batching manifest must fail loudly (it would also be
        // missing the batch artifacts): re-run `make artifacts`
        let text = sample("hlo-text/return-tuple")
            .replace("\"batch_slots\": 4,", "");
        let err = Manifest::parse(Path::new("/tmp/a"), &text).unwrap_err();
        assert!(err.to_string().contains("batch_slots"), "{err}");
    }

    #[test]
    fn rejects_bad_format() {
        assert!(Manifest::parse(Path::new("/tmp"), &sample("protobuf")).is_err());
    }

    #[test]
    fn rejects_missing_artifact() {
        let text = sample("hlo-text/return-tuple").replace("moe_one", "moe_uno");
        let err = Manifest::parse(Path::new("/tmp"), &text).unwrap_err();
        assert!(err.to_string().contains("moe_one"), "{err}");
    }

    #[test]
    fn loads_real_artifacts_if_present() {
        if let Ok(m) = Manifest::load_default() {
            assert_eq!(m.model.n_experts, 16);
            assert!(m.entry("moe_full").unwrap().file.exists());
        }
    }
}
