//! Simulation knobs: grouping policy, schedule policy, cache configuration,
//! workload lengths — the axes of every figure/table in the paper.

use std::fmt;

/// How experts are assigned to peripheral-sharing groups (§III-B).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GroupingPolicy {
    /// group size 1 — every crossbar keeps exclusive peripherals (baseline)
    None,
    /// uniform/random assignment ("U" in Fig. 5)
    Uniform,
    /// workload-sorted: pair lowest-load with highest-load ("S" in Fig. 5)
    Sorted,
}

impl fmt::Display for GroupingPolicy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            GroupingPolicy::None => write!(f, "none"),
            GroupingPolicy::Uniform => write!(f, "U"),
            GroupingPolicy::Sorted => write!(f, "S"),
        }
    }
}

/// Prefill token schedule (§III-D).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulePolicy {
    /// tokens strictly one by one (baseline)
    TokenWise,
    /// groups drain their queues independently ("C")
    Compact,
    /// compact + Algorithm 1 idle insertion for data reuse ("O")
    Reschedule,
}

impl fmt::Display for SchedulePolicy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SchedulePolicy::TokenWise => write!(f, "tokenwise"),
            SchedulePolicy::Compact => write!(f, "C"),
            SchedulePolicy::Reschedule => write!(f, "O"),
        }
    }
}

/// Which generation-stage caches are enabled (§III-C, Fig. 3/4).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CachePolicy {
    pub kv: bool,
    pub go: bool,
}

impl CachePolicy {
    pub const NONE: CachePolicy = CachePolicy { kv: false, go: false };
    pub const KV: CachePolicy = CachePolicy { kv: true, go: false };
    pub const GO: CachePolicy = CachePolicy { kv: false, go: true };
    pub const KVGO: CachePolicy = CachePolicy { kv: true, go: true };

    pub fn label(&self) -> &'static str {
        match (self.kv, self.go) {
            (false, false) => "no cache",
            (true, false) => "KV cache",
            (false, true) => "GO cache",
            (true, true) => "KVGO cache",
        }
    }
}

/// Which router drives the *prefill* trace (§II-A).  The paper's model is
/// expert-choice (its decode caches require it); token-choice is the
/// load-imbalanced regime that exercises the grouping study — Llama-MoE's
/// native router is top-k token-choice, and the paper keeps the model
/// structure unchanged, so both are faithful workloads.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutingMode {
    TokenChoice,
    ExpertChoice,
}

/// One simulated inference configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct SimConfig {
    /// experts per peripheral-sharing group (1, 2 or 4 in the paper)
    pub group_size: usize,
    pub grouping: GroupingPolicy,
    pub schedule: SchedulePolicy,
    pub cache: CachePolicy,
    /// prompt tokens (paper: 32)
    pub prompt_len: usize,
    /// generated tokens (paper: 8 to 64)
    pub gen_len: usize,
    /// prefill routing regime
    pub routing: RoutingMode,
    /// expert-popularity skew of the synthetic C4-substitute trace
    /// (0 = uniform; ~1 matches the imbalance the paper motivates with)
    pub skew: f64,
    /// RNG seed for trace generation / uniform grouping
    pub seed: u64,
}

impl SimConfig {
    /// Paper baseline: direct 3DCIM-style deployment — no sharing, no
    /// grouping, no scheduling, token-by-token, no caches.
    pub fn baseline() -> Self {
        SimConfig {
            group_size: 1,
            grouping: GroupingPolicy::None,
            schedule: SchedulePolicy::TokenWise,
            cache: CachePolicy::NONE,
            prompt_len: 32,
            gen_len: 8,
            routing: RoutingMode::ExpertChoice,
            skew: 1.0,
            seed: 2026,
        }
    }

    /// Named configuration like "S2O" / "U4C" (Fig. 5 labels).
    pub fn named(grouping: GroupingPolicy, group_size: usize,
                 schedule: SchedulePolicy) -> Self {
        SimConfig {
            group_size,
            grouping,
            schedule,
            ..Self::baseline()
        }
    }

    /// Paper's best-performance configuration (Table I middle column).
    pub fn s2o_kvgo() -> Self {
        SimConfig {
            cache: CachePolicy::KVGO,
            ..Self::named(GroupingPolicy::Sorted, 2, SchedulePolicy::Reschedule)
        }
    }

    /// Paper's best-density configuration (Table I right column).
    pub fn s4o_kvgo() -> Self {
        SimConfig {
            cache: CachePolicy::KVGO,
            ..Self::named(GroupingPolicy::Sorted, 4, SchedulePolicy::Reschedule)
        }
    }

    /// Fig. 5 style label, e.g. "S2O", "U4C", "base".
    pub fn label(&self) -> String {
        if self.group_size <= 1 {
            return "base".to_string();
        }
        let s = match self.schedule {
            SchedulePolicy::TokenWise => "T",
            SchedulePolicy::Compact => "C",
            SchedulePolicy::Reschedule => "O",
        };
        format!("{}{}{}", self.grouping, self.group_size, s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels() {
        assert_eq!(SimConfig::baseline().label(), "base");
        assert_eq!(SimConfig::s2o_kvgo().label(), "S2O");
        assert_eq!(SimConfig::s4o_kvgo().label(), "S4O");
        assert_eq!(
            SimConfig::named(GroupingPolicy::Uniform, 4,
                             SchedulePolicy::Compact)
            .label(),
            "U4C"
        );
    }

    #[test]
    fn cache_labels() {
        assert_eq!(CachePolicy::NONE.label(), "no cache");
        assert_eq!(CachePolicy::KVGO.label(), "KVGO cache");
    }

    #[test]
    fn baseline_is_paper_shape() {
        let b = SimConfig::baseline();
        assert_eq!(b.prompt_len, 32);
        assert_eq!(b.gen_len, 8);
        assert_eq!(b.group_size, 1);
        assert!(!b.cache.kv && !b.cache.go);
    }
}
