//! JSON config files: load/save a full experiment configuration
//! (simulation knobs + hardware overrides) so runs are reproducible from a
//! single artifact instead of a flag soup.
//!
//! ```json
//! {
//!   "sim": {"group_size": 2, "grouping": "S", "schedule": "O",
//!            "kv": true, "go": true, "prompt_len": 32, "gen_len": 8,
//!            "routing": "expert", "skew": 1.0, "seed": 2026},
//!   "hardware": {"xbar_area_ratio": 0.05, "dram_bytes_per_ns": 12.8}
//! }
//! ```
//!
//! Unknown keys are rejected (typos should fail, not silently default).

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::json::{self, Json};

use super::hardware::HardwareConfig;
use super::sim::{CachePolicy, GroupingPolicy, RoutingMode, SchedulePolicy,
                 SimConfig};

/// A fully resolved experiment configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct Experiment {
    pub sim: SimConfig,
    pub hw: HardwareConfig,
}

impl Default for Experiment {
    fn default() -> Self {
        Experiment { sim: SimConfig::baseline(), hw: HardwareConfig::paper() }
    }
}

impl Experiment {
    pub fn load(path: &Path) -> Result<Experiment> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Experiment> {
        let v = json::parse(text).map_err(|e| anyhow!("{e}"))?;
        let obj = v.as_obj().ok_or_else(|| anyhow!("config must be an object"))?;
        let mut exp = Experiment::default();
        for (key, val) in obj {
            match key.as_str() {
                "sim" => apply_sim(&mut exp.sim, val)?,
                "hardware" => apply_hw(&mut exp.hw, val)?,
                other => return Err(anyhow!("unknown top-level key '{other}'")),
            }
        }
        Ok(exp)
    }

    pub fn to_json(&self) -> Json {
        let s = &self.sim;
        Json::obj(vec![
            ("sim", Json::obj(vec![
                ("group_size", Json::num(s.group_size as f64)),
                ("grouping", Json::str(&s.grouping.to_string())),
                ("schedule", Json::str(match s.schedule {
                    SchedulePolicy::TokenWise => "T",
                    SchedulePolicy::Compact => "C",
                    SchedulePolicy::Reschedule => "O",
                })),
                ("kv", Json::Bool(s.cache.kv)),
                ("go", Json::Bool(s.cache.go)),
                ("prompt_len", Json::num(s.prompt_len as f64)),
                ("gen_len", Json::num(s.gen_len as f64)),
                ("routing", Json::str(match s.routing {
                    RoutingMode::TokenChoice => "token",
                    RoutingMode::ExpertChoice => "expert",
                })),
                ("skew", Json::num(s.skew)),
                ("seed", Json::num(s.seed as f64)),
            ])),
            ("hardware", Json::obj(vec![
                ("xbar_area_ratio", Json::num(self.hw.xbar_area_ratio)),
                ("core_latency_ns", Json::num(self.hw.core_latency_ns)),
                ("core_power_w", Json::num(self.hw.core_power_w)),
                ("core_area_mm2", Json::num(self.hw.core_area_mm2)),
                ("dram_bytes_per_ns", Json::num(self.hw.dram.bytes_per_ns)),
                ("dram_nj_per_byte",
                 Json::num(self.hw.dram.energy_nj_per_byte)),
            ])),
        ])
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())
            .with_context(|| format!("writing {}", path.display()))
    }
}

fn req_usize(v: &Json, key: &str) -> Result<usize> {
    v.as_usize().ok_or_else(|| anyhow!("'{key}' must be a non-negative int"))
}

fn req_f64(v: &Json, key: &str) -> Result<f64> {
    v.as_f64().ok_or_else(|| anyhow!("'{key}' must be a number"))
}

fn req_bool(v: &Json, key: &str) -> Result<bool> {
    v.as_bool().ok_or_else(|| anyhow!("'{key}' must be a bool"))
}

fn apply_sim(sim: &mut SimConfig, v: &Json) -> Result<()> {
    let obj = v.as_obj().ok_or_else(|| anyhow!("'sim' must be an object"))?;
    for (key, val) in obj {
        match key.as_str() {
            "group_size" => sim.group_size = req_usize(val, key)?,
            "grouping" => {
                sim.grouping = match val.as_str() {
                    Some("U") | Some("uniform") => GroupingPolicy::Uniform,
                    Some("S") | Some("sorted") => GroupingPolicy::Sorted,
                    Some("none") => GroupingPolicy::None,
                    _ => return Err(anyhow!("bad grouping (U|S|none)")),
                }
            }
            "schedule" => {
                sim.schedule = match val.as_str() {
                    Some("T") | Some("tokenwise") => SchedulePolicy::TokenWise,
                    Some("C") | Some("compact") => SchedulePolicy::Compact,
                    Some("O") | Some("resched") => SchedulePolicy::Reschedule,
                    _ => return Err(anyhow!("bad schedule (T|C|O)")),
                }
            }
            "kv" => sim.cache.kv = req_bool(val, key)?,
            "go" => sim.cache.go = req_bool(val, key)?,
            "prompt_len" => sim.prompt_len = req_usize(val, key)?,
            "gen_len" => sim.gen_len = req_usize(val, key)?,
            "routing" => {
                sim.routing = match val.as_str() {
                    Some("token") => RoutingMode::TokenChoice,
                    Some("expert") => RoutingMode::ExpertChoice,
                    _ => return Err(anyhow!("bad routing (token|expert)")),
                }
            }
            "skew" => sim.skew = req_f64(val, key)?,
            "seed" => sim.seed = req_usize(val, key)? as u64,
            other => return Err(anyhow!("unknown sim key '{other}'")),
        }
    }
    let _ = CachePolicy::NONE; // (type participates in the schema above)
    Ok(())
}

fn apply_hw(hw: &mut HardwareConfig, v: &Json) -> Result<()> {
    let obj = v
        .as_obj()
        .ok_or_else(|| anyhow!("'hardware' must be an object"))?;
    for (key, val) in obj {
        match key.as_str() {
            "xbar_area_ratio" => hw.xbar_area_ratio = req_f64(val, key)?,
            "core_latency_ns" => hw.core_latency_ns = req_f64(val, key)?,
            "core_power_w" => hw.core_power_w = req_f64(val, key)?,
            "core_area_mm2" => hw.core_area_mm2 = req_f64(val, key)?,
            "xbar_rows" => hw.xbar_rows = req_usize(val, key)?,
            "xbar_cols" => hw.xbar_cols = req_usize(val, key)?,
            "dram_bytes_per_ns" => {
                hw.dram.bytes_per_ns = req_f64(val, key)?
            }
            "dram_nj_per_byte" => {
                hw.dram.energy_nj_per_byte = req_f64(val, key)?
            }
            other => return Err(anyhow!("unknown hardware key '{other}'")),
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_default() {
        let exp = Experiment::default();
        let text = exp.to_json().to_string_pretty();
        let back = Experiment::parse(&text).unwrap();
        assert_eq!(back.sim, exp.sim);
        assert_eq!(back.hw.xbar_area_ratio, exp.hw.xbar_area_ratio);
    }

    #[test]
    fn parses_partial_override() {
        let exp = Experiment::parse(
            r#"{"sim": {"group_size": 4, "grouping": "S", "schedule": "O"},
                "hardware": {"xbar_area_ratio": 0.05}}"#,
        )
        .unwrap();
        assert_eq!(exp.sim.group_size, 4);
        assert_eq!(exp.sim.grouping, GroupingPolicy::Sorted);
        assert_eq!(exp.sim.prompt_len, 32); // default preserved
        assert_eq!(exp.hw.xbar_area_ratio, 0.05);
        assert_eq!(exp.hw.core_latency_ns, 130.0);
    }

    #[test]
    fn rejects_unknown_keys() {
        assert!(Experiment::parse(r#"{"sim": {"group_sice": 2}}"#).is_err());
        assert!(Experiment::parse(r#"{"simm": {}}"#).is_err());
        assert!(Experiment::parse(r#"{"hardware": {"adc": 1}}"#).is_err());
    }

    #[test]
    fn rejects_bad_values() {
        assert!(Experiment::parse(r#"{"sim": {"grouping": "X"}}"#).is_err());
        assert!(Experiment::parse(r#"{"sim": {"kv": "yes"}}"#).is_err());
        assert!(Experiment::parse(r#"{"sim": {"gen_len": -3}}"#).is_err());
    }

    #[test]
    fn save_and_load() {
        let dir = std::env::temp_dir().join("moepim_cfg_test.json");
        let mut exp = Experiment::default();
        exp.sim = SimConfig::s4o_kvgo();
        exp.hw.xbar_area_ratio = 0.05;
        exp.save(&dir).unwrap();
        let back = Experiment::load(&dir).unwrap();
        assert_eq!(back.sim, exp.sim);
        let _ = std::fs::remove_file(&dir);
    }
}
