//! MoE transformer model shapes.
//!
//! Two instantiations matter:
//! * [`MoeModelConfig::llama_moe_4_16`] — the paper's target (Llama-MoE-4/16,
//!   an MoE variant of Llama2-7B), used *analytically* by the simulator.
//! * the functional small-dims model from `artifacts/manifest.json`, used by
//!   the coordinator for real execution ([`crate::config::Manifest`]).

/// Shape of one MoE transformer block (all blocks are identical; the paper
/// simulates a single layer, §IV-A).
#[derive(Debug, Clone, PartialEq)]
pub struct MoeModelConfig {
    pub d_model: usize,
    pub n_experts: usize,
    /// experts activated per token (token-choice k / expert-choice average)
    pub top_k: usize,
    /// per-expert FFN width
    pub d_ff: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub n_layers: usize,
    pub vocab: usize,
}

impl MoeModelConfig {
    /// Llama-MoE-4/16 [4]: d=4096, 16 experts of d_ff = 11008/16 = 688,
    /// top-4 routing, 32 blocks.
    pub fn llama_moe_4_16() -> Self {
        MoeModelConfig {
            d_model: 4096,
            n_experts: 16,
            top_k: 4,
            d_ff: 688,
            n_heads: 32,
            d_head: 128,
            n_layers: 32,
            vocab: 32000,
        }
    }

    /// Expert-choice capacity for a `tokens`-token batch: each expert
    /// selects `tokens * top_k / n_experts` tokens (Zhou et al. [12]).
    /// The paper fixes this at the prefill value during generation so the
    /// GO output cache stays at its static `k x E x d` size.
    pub fn expert_capacity(&self, tokens: usize) -> usize {
        (tokens * self.top_k).div_ceil(self.n_experts).max(1)
    }

    /// MAC count of one expert's FFN on one token (up D x F + down F x D).
    pub fn macs_per_expert_token(&self) -> u64 {
        2 * (self.d_model as u64) * (self.d_ff as u64)
    }

    /// MAC count of the gate MVM for one token (D x E, digital units).
    pub fn gate_macs_per_token(&self) -> u64 {
        (self.d_model as u64) * (self.n_experts as u64)
    }

    /// MACs of one attention step at context length `l` (QKV + scores +
    /// values + output projection), per token processed.
    pub fn attn_macs_per_token(&self, l: usize) -> u64 {
        let d = self.d_model as u64;
        let proj = 4 * d * d; // Q, K, V, O projections
        let attend = 2 * (l as u64) * d; // QK^T + AV across heads
        proj + attend
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_dims() {
        let m = MoeModelConfig::llama_moe_4_16();
        assert_eq!(m.d_model, 4096);
        assert_eq!(m.n_experts, 16);
        assert_eq!(m.d_ff * m.n_experts, 11008); // Llama2-7B FFN split 16-way
    }

    #[test]
    fn capacity_paper_value() {
        let m = MoeModelConfig::llama_moe_4_16();
        // 32 prompt tokens * 4 / 16 experts = 8 tokens per expert
        assert_eq!(m.expert_capacity(32), 8);
        assert_eq!(m.expert_capacity(1), 1); // never zero
        assert_eq!(m.expert_capacity(33), 9); // ceil
    }

    #[test]
    fn mac_counts() {
        let m = MoeModelConfig::llama_moe_4_16();
        assert_eq!(m.macs_per_expert_token(), 2 * 4096 * 688);
        assert_eq!(m.gate_macs_per_token(), 4096 * 16);
        assert!(m.attn_macs_per_token(64) > m.attn_macs_per_token(32));
    }
}
