//! Configuration system: model dims, hardware constants, simulation knobs,
//! and the functional-artifact manifest.
//!
//! Everything is plain data with paper-faithful defaults; the CLI and
//! examples override via flags, and the manifest variant is read from
//! `artifacts/manifest.json` (written by `python -m compile.aot`).

pub mod file;
pub mod hardware;
pub mod manifest;
pub mod model;
pub mod sim;

pub use file::Experiment;
pub use hardware::{DigitalConfig, DramConfig, HardwareConfig};
pub use manifest::Manifest;
pub use model::MoeModelConfig;
pub use sim::{CachePolicy, GroupingPolicy, RoutingMode, SchedulePolicy, SimConfig};
