//! Hardware constants: the HERMES PIM core spec, DRAM, and the digital
//! (non-PIM) units.
//!
//! The paper's §IV-A setup: HERMES cores [17-19] (256x256 crossbar, 8-bit
//! I/O), 130 ns / 0.096 W per core activation, 0.635 mm² core area, with the
//! crossbar itself accounting for 40 % of the core (peripherals — dominated
//! by ADCs [8] — take the rest).  All other components (attention digital
//! units, DRAM for the KV/GO caches) follow 3DCIM [7] assumptions; since
//! that simulator is closed, we use the polynomial fits documented in
//! DESIGN.md §8 with constants calibrated against Table I's baseline column
//! (see `eval::calibration`).

/// DRAM model for the off-chip KV + GO caches.
#[derive(Debug, Clone, PartialEq)]
pub struct DramConfig {
    /// sustained bandwidth, bytes per ns (e.g. 12.8 GB/s ~= 12.8 B/ns)
    pub bytes_per_ns: f64,
    /// access energy per byte, nJ (DDR4-ish ~20 pJ/bit -> 0.16 nJ/B)
    pub energy_nj_per_byte: f64,
    /// fixed per-burst latency, ns
    pub burst_latency_ns: f64,
}

impl DramConfig {
    pub fn paper() -> Self {
        DramConfig {
            bytes_per_ns: 5.94,
            energy_nj_per_byte: 0.155,
            burst_latency_ns: 30.0,
        }
    }

    /// (latency_ns, energy_nj) of moving `bytes` to/from DRAM.
    pub fn transfer(&self, bytes: u64) -> (f64, f64) {
        if bytes == 0 {
            return (0.0, 0.0);
        }
        (
            self.burst_latency_ns + bytes as f64 / self.bytes_per_ns,
            bytes as f64 * self.energy_nj_per_byte,
        )
    }
}

/// Digital units: attention (MHA stays off-PIM, as in 3DCIM [7]) and the
/// gate MVM.  Costs are polynomial fits in the token/context length
/// (DESIGN.md §8); `*_ns`/`*_nj` name the fitted coefficients.
#[derive(Debug, Clone, PartialEq)]
pub struct DigitalConfig {
    /// attention cost, linear term: per token per unit context, ns
    pub attn_ns_per_token_ctx: f64,
    /// attention cost, fixed per-token term (projections etc.), ns
    pub attn_ns_per_token: f64,
    /// attention energy analogues, nJ
    pub attn_nj_per_token_ctx: f64,
    pub attn_nj_per_token: f64,
    /// fraction of the per-token constant paid when *re-processing* a past
    /// token with its K/V already cached (0 = projections fully reused,
    /// only the attend term remains) — the no-GO decode recompute path
    pub kv_reuse_factor: f64,
    /// gate MVM (D x E) per token fed, ns / nJ
    pub gate_ns_per_token: f64,
    pub gate_nj_per_token: f64,
    /// digital top-k / softmax / TopKUpdate per routing decision, ns / nJ
    pub route_ns_per_token: f64,
    pub route_nj_per_token: f64,
}

impl DigitalConfig {
    pub fn paper() -> Self {
        DigitalConfig {
            // Calibrated against Table I baseline (see eval::calibration):
            // attention throughput of the digital units is the decode-stage
            // bottleneck without KV cache.
            attn_ns_per_token_ctx: 51.0,
            attn_ns_per_token: 4951.0,
            attn_nj_per_token_ctx: 255.0,
            attn_nj_per_token: 1797.0,
            kv_reuse_factor: 0.0,
            gate_ns_per_token: 95.0,
            gate_nj_per_token: 170.0,
            route_ns_per_token: 12.0,
            route_nj_per_token: 6.0,
        }
    }
}

/// One HERMES-style PIM core (crossbar + its peripheral set).
#[derive(Debug, Clone, PartialEq)]
pub struct HardwareConfig {
    /// crossbar rows (cells per bit-line)
    pub xbar_rows: usize,
    /// crossbar columns
    pub xbar_cols: usize,
    /// I/O resolution, bits (DAC in / ADC out)
    pub io_bits: u32,
    /// latency of activating one core for one MVM, ns
    pub core_latency_ns: f64,
    /// power while a core is active, W (paper prints "0.096 nW", a typo —
    /// nanowatts would make a whole-chip MVM cheaper than a single DRAM
    /// bit; HERMES-class cores dissipate ~0.1 W)
    pub core_power_w: f64,
    /// full core area (crossbar + exclusive peripherals), mm²
    pub core_area_mm2: f64,
    /// fraction of core area that is the crossbar itself (HERMES: 40 %;
    /// ISAAC-style designs: 5 % — §IV-B's generalisation)
    pub xbar_area_ratio: f64,
    /// energy for latching/broadcasting one token's activation vector into
    /// a group's DAC inputs, per byte, nJ (on-chip, cheaper than DRAM)
    pub input_nj_per_byte: f64,
    /// latency of an input broadcast that is NOT hidden by the pipeline
    /// (the paper hides scheduler + aligned transfers; only group-local
    /// refetches stall), ns
    pub input_stall_ns: f64,
    pub dram: DramConfig,
    pub digital: DigitalConfig,
}

impl HardwareConfig {
    /// The paper's experimental setup (§IV-A).
    pub fn paper() -> Self {
        HardwareConfig {
            xbar_rows: 256,
            xbar_cols: 256,
            io_bits: 8,
            core_latency_ns: 130.0,
            core_power_w: 0.096,
            core_area_mm2: 0.635,
            xbar_area_ratio: 0.40,
            input_nj_per_byte: 0.02,
            input_stall_ns: 8.0,
            dram: DramConfig::paper(),
            digital: DigitalConfig::paper(),
        }
    }

    /// ISAAC-like peripheral-heavy variant (crossbar only 5 % of core area,
    /// §IV-B's "generalised" case [20]).
    pub fn isaac_ratio() -> Self {
        HardwareConfig { xbar_area_ratio: 0.05, ..Self::paper() }
    }

    /// Energy of one core activation (one MVM round), nJ.
    pub fn core_energy_nj(&self) -> f64 {
        self.core_latency_ns * self.core_power_w
    }

    /// Crossbar-only area of one core, mm².
    pub fn xbar_area_mm2(&self) -> f64 {
        self.core_area_mm2 * self.xbar_area_ratio
    }

    /// Peripheral-only area of one core (ADCs etc.), mm².
    pub fn periph_area_mm2(&self) -> f64 {
        self.core_area_mm2 * (1.0 - self.xbar_area_ratio)
    }

    /// MACs one core performs per activation.
    pub fn macs_per_activation(&self) -> u64 {
        (self.xbar_rows * self.xbar_cols) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_constants() {
        let hw = HardwareConfig::paper();
        assert_eq!(hw.xbar_rows, 256);
        assert_eq!(hw.io_bits, 8);
        assert!((hw.core_energy_nj() - 12.48).abs() < 1e-9); // 130ns * 0.096W
        assert!((hw.xbar_area_mm2() - 0.254).abs() < 1e-9);
        assert!((hw.periph_area_mm2() - 0.381).abs() < 1e-9);
    }

    #[test]
    fn area_partition_sums() {
        for hw in [HardwareConfig::paper(), HardwareConfig::isaac_ratio()] {
            assert!(
                (hw.xbar_area_mm2() + hw.periph_area_mm2() - hw.core_area_mm2)
                    .abs()
                    < 1e-12
            );
        }
    }

    #[test]
    fn dram_transfer_scales() {
        let d = DramConfig::paper();
        let (l1, e1) = d.transfer(1024);
        let (l2, e2) = d.transfer(2048);
        assert!(l2 > l1 && e2 > e1);
        assert!((e2 / e1 - 2.0).abs() < 1e-9); // energy linear in bytes
        assert_eq!(d.transfer(0), (0.0, 0.0)); // no burst cost for nothing
    }

    #[test]
    fn isaac_has_smaller_xbar_share() {
        assert!(
            HardwareConfig::isaac_ratio().xbar_area_mm2()
                < HardwareConfig::paper().xbar_area_mm2()
        );
    }
}
