"""Pure-jnp correctness oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here using the
*same arithmetic* (quantise -> per-crossbar-slice integer MVM -> ADC readout
quantisation -> dequantise) expressed as plain jnp ops.  The pytest suite
asserts allclose between kernel and oracle across shape/seed sweeps
(hypothesis-driven); because every intermediate is an exactly-representable
integer in f32 (|partial| <= 128*127*127 < 2^24) the match is bit-exact.

The quantisation chain models the analog signal path of a HERMES-style PIM
core (DESIGN.md §Hardware-Adaptation):

  DAC (8-bit input)      -> symmetric int8 quantisation of activations
  crossbar (weights)     -> symmetric int8 quantisation of weights,
                            K split into xbar_rows-row slices (one slice ==
                            one physical crossbar's worth of bit-lines)
  ADC (8-bit readout)    -> each slice's partial sum snapped to a uniform
                            grid with 2^(adc_bits-1)-1 positive levels over
                            the slice's analog full-scale range
  digital accumulation   -> dequantised slice results summed in f32
"""

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Quantisation primitives
# ---------------------------------------------------------------------------

def sym_quant(x: jnp.ndarray, bits: int, axis=None):
    """Symmetric quantisation; per-tensor (axis=None) or per-row (axis=-1).

    Returns (q, scale) with q an integer-valued f32 tensor in
    [-(2^(bits-1)-1), 2^(bits-1)-1] and x ~= q * scale.

    Weights are quantised per-tensor (cell conductances programmed once at
    deploy).  Activations are quantised per-row: each token's vector drives
    the DACs with its own range register, which also keeps the pipeline
    row-local — a single-token call produces bit-identical results to the
    same row inside a batch (the property the GO-cache decode path relies
    on; see test_model.test_moe_apply_row_local).
    """
    qmax = float(2 ** (bits - 1) - 1)
    amax = jnp.max(jnp.abs(x)) if axis is None else         jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    # Avoid a zero scale for all-zero tensors; the quantised tensor is then
    # all zeros regardless of scale.
    scale = jnp.where(amax > 0, amax / qmax, 1.0)
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax)
    return q, scale


def adc_step(slice_rows: int, in_bits: int, adc_bits: int,
             range_factor: float) -> float:
    """ADC grid step for one crossbar slice.

    The theoretical analog full-scale of a slice is slice_rows * qmax_in *
    qmax_w (every cell at max conductance, every input at max voltage), but
    real HERMES silicon ranges its linearized CCO ADCs per column to the
    *observed* signal distribution [17-19]; `range_factor` models that
    calibration (the resolved range is full_scale / range_factor, clipped
    beyond).  The step is exact-integer f32 arithmetic so kernel and oracle
    agree bit-for-bit.
    """
    qmax_in = float(2 ** (in_bits - 1) - 1)
    levels = float(2 ** (adc_bits - 1) - 1)
    full_scale = slice_rows * qmax_in * qmax_in
    return max(full_scale / range_factor / levels, 1.0)


def adc_readout(partial: jnp.ndarray, slice_rows: int, in_bits: int,
                adc_bits: int, range_factor: float = 16.0,
                noise_std: float = 0.0, noise_key=None) -> jnp.ndarray:
    """Emulate the ranged-ADC quantisation of one slice's partial sums:
    snap to the calibrated grid and clip at the resolved range.

    `noise_std` (in ADC steps) adds Gaussian analog read noise *before*
    quantisation — the PCM read-noise model mirrored by the rust
    `hw::noise` module (paper future work).  Requires a `noise_key`.
    """
    levels = float(2 ** (adc_bits - 1) - 1)
    step = adc_step(slice_rows, in_bits, adc_bits, range_factor)
    if noise_std > 0.0:
        assert noise_key is not None, "noisy readout needs a PRNG key"
        partial = partial + jax.random.normal(
            noise_key, partial.shape) * (noise_std * step)
    return jnp.clip(jnp.round(partial / step), -levels, levels) * step


# ---------------------------------------------------------------------------
# Reference kernels
# ---------------------------------------------------------------------------

def crossbar_matmul_ref(x: jnp.ndarray, w: jnp.ndarray, *, xbar_rows: int,
                        dac_bits: int = 8, adc_bits: int = 8,
                        range_factor: float = 16.0) -> jnp.ndarray:
    """Reference for kernels.crossbar.crossbar_matmul.

    x: [M, K] activations, w: [K, N] weights; K must be a multiple of
    xbar_rows.  Returns the dequantised [M, N] product of the emulated
    analog pipeline.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    assert k % xbar_rows == 0, f"K={k} not a multiple of xbar_rows={xbar_rows}"
    qx, sx = sym_quant(x, dac_bits, axis=-1)   # per-row DAC ranging
    qw, sw = sym_quant(w, dac_bits)            # per-tensor cell programming
    n_slices = k // xbar_rows
    acc = jnp.zeros((m, n), dtype=jnp.float32)
    for s in range(n_slices):
        lo = s * xbar_rows
        part = qx[:, lo:lo + xbar_rows] @ qw[lo:lo + xbar_rows, :]
        acc = acc + adc_readout(part, xbar_rows, dac_bits, adc_bits,
                                 range_factor)
    return acc * (sx * sw)


def matmul_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Reference for kernels.gate.digital_matmul (full-precision, digital)."""
    return x.astype(jnp.float32) @ w.astype(jnp.float32)


def expert_ffn_ref(x: jnp.ndarray, w_up: jnp.ndarray, w_down: jnp.ndarray, *,
                   xbar_rows: int, dac_bits: int = 8, adc_bits: int = 8,
                   range_factor: float = 16.0) -> jnp.ndarray:
    """Reference for the 2-matrix PIM expert FFN: silu(x@Wup) @ Wdown.

    Matches the paper's 96-crossbars-per-expert accounting (48 up + 48 down
    tiles at full dims, DESIGN.md §7).  SiLU runs digitally after readout.
    """
    h = crossbar_matmul_ref(x, w_up, xbar_rows=xbar_rows, dac_bits=dac_bits,
                            adc_bits=adc_bits, range_factor=range_factor)
    h = h * jax.nn.sigmoid(h)
    return crossbar_matmul_ref(h, w_down, xbar_rows=xbar_rows,
                               dac_bits=dac_bits, adc_bits=adc_bits,
                               range_factor=range_factor)


def gate_scores_ref(x: jnp.ndarray, w_g: jnp.ndarray) -> jnp.ndarray:
    """Gate scores [T, E]; the gate runs on the digital units (full f32)."""
    return matmul_ref(x, w_g)


def expert_choice_gates_ref(scores: jnp.ndarray, capacity: int,
                            valid_len=None) -> jnp.ndarray:
    """Expert-choice routing (Zhou et al. [12]) as dense gate weights.

    probs = softmax over experts per token; each expert selects its top
    `capacity` tokens by prob; gates[t, e] = probs[t, e] if selected else 0.
    `valid_len` masks padded tokens (they are never selected and receive no
    experts).  Deterministic tie-break: earlier token wins, matching the
    rust GoCache implementation (cache::go).
    """
    t, e = scores.shape
    probs = jax.nn.softmax(scores, axis=-1)
    if valid_len is not None:
        tok = jnp.arange(t)[:, None]
        probs = jnp.where(tok < valid_len, probs, -1.0)
    # top-`capacity` per expert column; stable argsort of the negated probs
    # implements the earlier-token-wins tie-break.
    order = jnp.argsort(-probs, axis=0, stable=True)  # [T, E]
    rank = jnp.argsort(order, axis=0, stable=True)    # rank of each token
    sel = rank < capacity
    if valid_len is not None:
        sel = sel & (jnp.arange(t)[:, None] < valid_len)
    return jnp.where(sel, jnp.maximum(probs, 0.0), 0.0)


def moe_apply_ref(x: jnp.ndarray, gates: jnp.ndarray, w_up: jnp.ndarray,
                  w_down: jnp.ndarray, *, xbar_rows: int, dac_bits: int = 8,
                  adc_bits: int = 8,
                  range_factor: float = 16.0) -> jnp.ndarray:
    """Dense-masked MoE: y = sum_e gates[:, e] * FFN_e(x).

    w_up: [E, D, F], w_down: [E, F, D].  The functional path computes every
    expert and masks; the sparsity savings are what the L3 *simulator*
    models (the real chip simply never activates unselected crossbars).
    """
    t, d = x.shape
    e = gates.shape[1]
    y = jnp.zeros((t, d), dtype=jnp.float32)
    for i in range(e):
        yi = expert_ffn_ref(x, w_up[i], w_down[i], xbar_rows=xbar_rows,
                            dac_bits=dac_bits, adc_bits=adc_bits,
                            range_factor=range_factor)
        y = y + gates[:, i:i + 1] * yi
    return y


# ---------------------------------------------------------------------------
# Attention / norm oracles (digital units in the paper's chip)
# ---------------------------------------------------------------------------

def rmsnorm_ref(x: jnp.ndarray, g: jnp.ndarray, eps: float = 1e-5):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * g


def attention_prefill_ref(x, wq, wk, wv, wo, n_heads, d_head,
                          valid_len=None):
    """Causal MHA over a (possibly padded) [T, D] sequence, f32 digital.

    Returns (out [T, D], k [T, H, Dh], v [T, H, Dh]) so the caller can seed
    the KV cache.
    """
    t, d = x.shape
    q = (x @ wq).reshape(t, n_heads, d_head)
    k = (x @ wk).reshape(t, n_heads, d_head)
    v = (x @ wv).reshape(t, n_heads, d_head)
    logits = jnp.einsum("thd,shd->hts", q, k) / jnp.sqrt(float(d_head))
    pos = jnp.arange(t)
    mask = pos[None, :] <= pos[:, None]  # causal [t, s]
    if valid_len is not None:
        mask = mask & (pos[None, :] < valid_len)
    logits = jnp.where(mask[None, :, :], logits, -1e30)
    attn = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("hts,shd->thd", attn, v).reshape(t, d)
    return out @ wo, k, v


def attention_decode_ref(x1, k_cache, v_cache, pos, wq, wk, wv, wo,
                         n_heads, d_head):
    """One cached decode step: x1 [1, D], caches [S, H, Dh], pos scalar.

    Attends over cache rows [0, pos] after writing the new K/V at `pos`.
    Returns (out [1, D], k_new [1, H, Dh], v_new [1, H, Dh]).
    """
    s = k_cache.shape[0]
    q = (x1 @ wq).reshape(n_heads, d_head)
    k_new = (x1 @ wk).reshape(1, n_heads, d_head)
    v_new = (x1 @ wv).reshape(1, n_heads, d_head)
    k_all = jax.lax.dynamic_update_slice(k_cache, k_new, (pos, 0, 0))
    v_all = jax.lax.dynamic_update_slice(v_cache, v_new, (pos, 0, 0))
    logits = jnp.einsum("hd,shd->hs", q, k_all) / jnp.sqrt(float(d_head))
    mask = jnp.arange(s) <= pos
    logits = jnp.where(mask[None, :], logits, -1e30)
    attn = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("hs,shd->hd", attn, v_all).reshape(1, n_heads * d_head)
    return out @ wo, k_new, v_new
