"""Pallas digital matmul kernel — gate network and other full-precision ops.

The paper computes the gate on the digital units (it is tiny: one D x E MVM
per token), so unlike kernels.crossbar there is no DAC/ADC quantisation:
plain f32 tiled matmul with MXU-shaped blocks.  Oracle: ref.matmul_ref.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .crossbar import _pick_tile


def _matmul_kernel(x_ref, w_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                          preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("tile_m", "tile_n", "tile_k",
                                             "interpret"))
def digital_matmul(x: jnp.ndarray, w: jnp.ndarray, *, tile_m: int = 32,
                   tile_n: int = 128, tile_k: int = 128,
                   interpret: bool = True) -> jnp.ndarray:
    """f32 tiled matmul: x [M, K] @ w [K, N] -> [M, N]."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    tm = _pick_tile(m, tile_m)
    tn = _pick_tile(n, tile_n)
    tk = _pick_tile(k, tile_k)
    grid = (m // tm, n // tn, k // tk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, s: (i, s)),
            pl.BlockSpec((tk, tn), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x.astype(jnp.float32), w.astype(jnp.float32))


def gate_scores(x: jnp.ndarray, w_g: jnp.ndarray, *,
                interpret: bool = True) -> jnp.ndarray:
    """Gate scores [T, E] = x @ Wg on the digital path."""
    return digital_matmul(x, w_g, tile_n=min(128, w_g.shape[1]),
                          interpret=interpret)
