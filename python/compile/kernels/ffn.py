"""Pallas expert-FFN: the per-expert PIM pipeline up-MVM -> SiLU -> down-MVM.

Two crossbar_matmul calls with the digital SiLU between readouts — exactly
the per-expert structure the paper maps to 96 crossbars (48 up-tiles +
48 down-tiles at full dims, DESIGN.md §7).  Oracle: ref.expert_ffn_ref.
"""

import jax
import jax.numpy as jnp

from .crossbar import crossbar_matmul


def expert_ffn(x: jnp.ndarray, w_up: jnp.ndarray, w_down: jnp.ndarray, *,
               xbar_rows: int, dac_bits: int = 8, adc_bits: int = 8,
               range_factor: float = 16.0,
               interpret: bool = True) -> jnp.ndarray:
    """silu(x @ Wup) @ Wdown through the emulated analog pipeline.

    x: [M, D]; w_up: [D, F]; w_down: [F, D]; D and F multiples of xbar_rows.
    """
    h = crossbar_matmul(x, w_up, xbar_rows=xbar_rows, dac_bits=dac_bits,
                        adc_bits=adc_bits, range_factor=range_factor,
                        interpret=interpret)
    h = h * jax.nn.sigmoid(h)  # SiLU on the digital units after ADC readout
    return crossbar_matmul(h, w_down, xbar_rows=xbar_rows, dac_bits=dac_bits,
                           adc_bits=adc_bits, range_factor=range_factor,
                           interpret=interpret)


def moe_apply(x: jnp.ndarray, gates: jnp.ndarray, w_up: jnp.ndarray,
              w_down: jnp.ndarray, *, xbar_rows: int, dac_bits: int = 8,
              adc_bits: int = 8, range_factor: float = 16.0,
              interpret: bool = True) -> jnp.ndarray:
    """Dense-masked MoE over all experts: y = sum_e gates[:, e] * FFN_e(x).

    w_up: [E, D, F]; w_down: [E, F, D]; gates: [T, E] (zero where the expert
    did not select the token).  The loop unrolls at trace time into E
    independent pipelines in one HLO module — the chip analogy is all expert
    crossbars physically present, with the gate mask standing in for "not
    activated" (the energy/latency consequence of which is the L3
    simulator's job).
    """
    t, d = x.shape
    e = gates.shape[1]
    y = jnp.zeros((t, d), dtype=jnp.float32)
    for i in range(e):
        yi = expert_ffn(x, w_up[i], w_down[i], xbar_rows=xbar_rows,
                        dac_bits=dac_bits, adc_bits=adc_bits,
                        range_factor=range_factor, interpret=interpret)
        y = y + gates[:, i:i + 1] * yi
    return y
