"""Pallas crossbar-MVM kernel — the PIM compute hot-spot (L1).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's compute
substrate is a 256x256 analog crossbar with weight-stationary cells, 8-bit
DAC inputs and 8-bit ADC readout.  On a TPU-shaped machine the same insight
maps to a BlockSpec-tiled matmul:

  * the weight block for one (k-slice, n-tile) is pinned in VMEM across the
    grid's M dimension — the VMEM-resident block *is* the programmed
    crossbar;
  * activations stream HBM->VMEM one M-tile at a time, like DAC streaming;
  * each K-slice's partial sum is snapped to the ADC grid before the digital
    f32 accumulation, mirroring per-bit-line readout resolution.

The kernel runs under interpret=True (CPU PJRT cannot execute Mosaic
custom-calls); tiling is still chosen MXU-shaped (multiples of 128) so the
same code lowers sensibly on real hardware.  Correctness oracle:
ref.crossbar_matmul_ref (bit-exact, see ref.py docstring).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _pick_tile(dim: int, pref: int) -> int:
    """Largest tile <= pref that divides dim (dims here are powers of two)."""
    t = min(pref, dim)
    while dim % t != 0:
        t //= 2
    return max(t, 1)


def _xbar_kernel(qx_ref, qw_ref, o_ref, *, step: float, levels: float):
    """One (m-tile, n-tile, k-slice) grid cell.

    Grid order is (m, n, k) with k innermost; o_ref accumulates across the
    k dimension.  qx/qw hold integer-valued f32 (already DAC/cell quantised);
    the matmul partial sum is exact in f32, then snapped to the ranged-ADC
    grid and clipped at the resolved range (ref.adc_readout).
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    part = jnp.dot(qx_ref[...], qw_ref[...],
                   preferred_element_type=jnp.float32)
    # ADC readout: snap the slice's analog partial sum to the ADC grid.
    o_ref[...] += jnp.clip(jnp.round(part / step), -levels, levels) * step


@functools.partial(jax.jit, static_argnames=("xbar_rows", "dac_bits",
                                             "adc_bits", "range_factor",
                                             "tile_m", "tile_n", "interpret"))
def crossbar_matmul(x: jnp.ndarray, w: jnp.ndarray, *, xbar_rows: int,
                    dac_bits: int = 8, adc_bits: int = 8,
                    range_factor: float = 16.0, tile_m: int = 32,
                    tile_n: int = 128, interpret: bool = True) -> jnp.ndarray:
    """Emulated analog MVM: y ~= x @ w through the DAC/crossbar/ADC path.

    x: [M, K] f32, w: [K, N] f32; K % xbar_rows == 0.  Quantisation happens
    outside the kernel: weights per-tensor (cell conductances programmed at
    deploy), activations per-row (each token sets its own DAC range, which
    keeps the pipeline row-local — see ref.sym_quant).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and k % xbar_rows == 0, (x.shape, w.shape, xbar_rows)

    qx, sx = ref.sym_quant(x, dac_bits, axis=-1)  # per-row DAC ranging
    qw, sw = ref.sym_quant(w, dac_bits)           # per-tensor programming

    tm = _pick_tile(m, tile_m)
    tn = _pick_tile(n, tile_n)
    n_slices = k // xbar_rows

    levels = float(2 ** (adc_bits - 1) - 1)
    step = ref.adc_step(xbar_rows, dac_bits, adc_bits, range_factor)

    grid = (m // tm, n // tn, n_slices)
    out = pl.pallas_call(
        functools.partial(_xbar_kernel, step=step, levels=levels),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, xbar_rows), lambda i, j, s: (i, s)),
            pl.BlockSpec((xbar_rows, tn), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(qx, qw)
    return out * (sx * sw)


def vmem_bytes(tile_m: int, tile_n: int, xbar_rows: int) -> int:
    """Static VMEM footprint of one grid cell (f32), for the §Perf estimate:
    activation block + weight block + output accumulator block."""
    return 4 * (tile_m * xbar_rows + xbar_rows * tile_n + tile_m * tile_n)
