"""L1: Pallas kernels for the paper's compute hot-spot.

- crossbar: emulated analog crossbar MVM (DAC -> slice MVM -> ADC), tiled
  weight-stationary in VMEM; the PIM hot path.
- ffn: per-expert up/SiLU/down pipeline built from crossbar MVMs.
- gate: full-precision digital matmul (gate network and other digital ops).
- ref: pure-jnp oracles; pytest asserts kernel == oracle bit-exactly.
"""

from . import crossbar, ffn, gate, ref  # noqa: F401
