"""AOT compile path: lower every exported L2 computation to HLO *text*.

Interchange format is HLO text, NOT `lowered.compiler_ir("hlo").serialize()`:
jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the rust
side's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/gen_hlo.py and
aot_recipe.md).

Outputs (per ModelConfig, all weights baked in as constants):

  artifacts/
    manifest.json           dims + artifact table (read by rust config)
    embed_prefill.hlo.txt   ids[S]i32                  -> x[S,D]
    embed_one.hlo.txt       ids[1]i32                  -> x[1,D]
    attn_prefill.hlo.txt    x[S,D], len[1]i32          -> h[S,D], k[S,H,Dh], v[S,H,Dh]
    attn_decode.hlo.txt     x[1,D], k[S,H,Dh], v[S,H,Dh], pos[1]i32
                                                       -> h[1,D], k1[1,H,Dh], v1[1,H,Dh]
    gate_full.hlo.txt       h[S,D]                     -> scores[S,E]
    gate_one.hlo.txt        h[1,D]                     -> scores[1,E]
    moe_full.hlo.txt        h[S,D], gates[S,E]         -> y[S,D]
    moe_one.hlo.txt         h[1,D], gates[1,E]         -> y[1,D]
    moe_one_sparse.hlo.txt  h[1,D], idx[K]i32, gate[K]  -> y[1,D]  (K=capacity)
    logits_one.hlo.txt      h[1,D]                     -> logits[1,V]

  Slot-batched decode (serving engine, B = cfg.batch_slots):

    embed_batch.hlo.txt       ids[B]i32                -> x[B,D]
    attn_decode_batch.hlo.txt x[B,D], k[B,S,H,Dh], v[B,S,H,Dh], pos[B]i32
                                                       -> h[B,D], k1[B,H,Dh], v1[B,H,Dh]
    gate_batch.hlo.txt        h[B,D]                   -> scores[B,E]
    moe_batch_sparse.hlo.txt  h[B,D], idx[B,K]i32, gate[B,K] -> y[B,D]

  Depth L > 1 (cfg.n_layers_functional / --layers): every per-block family
  (attn_*, gate_*, moe_*) is lowered once per layer with that layer's
  weights baked in; layer 0 keeps the bare name and layers >= 1 append
  `_l{layer}` (see layer_artifact), so an L=1 set is byte-identical to the
  single-block one.  embed_* and logits_one are shared across the stack.

`make artifacts` is a no-op when inputs are unchanged (manifest.json is the
stamp).  Python never runs on the request path after this.
"""

import argparse
import dataclasses
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .config import DEFAULT, ModelConfig


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the rust
    side unwraps with to_tuple{1,3}())."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True: the baked weights must survive the text
    # round-trip (the default printer elides them as '{...}', which parses
    # back as garbage).  f32 prints at 9 significant digits == exact.
    return comp.as_hlo_text(True)


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def layer_artifact(name: str, layer: int) -> str:
    """Artifact name of `name` at `layer`.  Layer 0 keeps the bare name so
    an L=1 artifact set is byte-identical to the pre-multi-layer one (and
    so every seed stream survives)."""
    return name if layer == 0 else f"{name}_l{layer}"


def build_entries(cfg: ModelConfig):
    """(name, fn, example_args) for every exported executable.

    Shared entries (embed_*, logits_one) appear once; per-block entries
    (attention / gate / MoE families) appear once per functional layer,
    named via `layer_artifact`, each with that layer's weights baked in.
    """
    params = model.init_params(cfg)
    s, d, e = cfg.max_seq, cfg.d_model, cfg.n_experts
    h, dh = cfg.n_heads, cfg.d_head

    def embed(ids):
        return model.embed_tokens(params, cfg, ids)

    def logits(hh):
        return model.logits(params, cfg, hh)

    i32 = jnp.int32
    bsl, cap = cfg.batch_slots, cfg.expert_capacity
    entries = [
        ("embed_prefill", embed, (_spec((s,), i32),)),
        ("embed_one", embed, (_spec((1,), i32),)),
        ("embed_batch", embed, (_spec((bsl,), i32),)),
        ("logits_one", logits, (_spec((1, d)),)),
    ]

    for layer in range(cfg.n_layers_functional):
        # bind the loop variable via default args (late binding otherwise)
        def attn_prefill(x, valid_len, layer=layer):
            return model.attn_prefill(params, cfg, x, valid_len[0],
                                      layer=layer)

        def attn_decode(x1, kc, vc, pos, layer=layer):
            return model.attn_decode(params, cfg, x1, kc, vc, pos[0],
                                     layer=layer)

        def gate(hh, layer=layer):
            return model.gate_scores(params, cfg, hh, layer=layer)

        def moe(hh, gates, layer=layer):
            return model.moe_apply(params, cfg, hh, gates, layer=layer)

        def moe_sparse(hh, idx, gates, layer=layer):
            return model.moe_apply_sparse(params, cfg, hh, idx, gates,
                                          layer=layer)

        def attn_decode_batch(xb, kc, vc, pos, layer=layer):
            return model.attn_decode_batch(params, cfg, xb, kc, vc, pos,
                                           layer=layer)

        def gate_batch(hb, layer=layer):
            return model.gate_batch(params, cfg, hb, layer=layer)

        def moe_batch_sparse(hb, idx, gates, layer=layer):
            return model.moe_batch_sparse(params, cfg, hb, idx, gates,
                                          layer=layer)

        nm = lambda base: layer_artifact(base, layer)  # noqa: E731
        entries += [
            (nm("attn_prefill"), attn_prefill,
             (_spec((s, d)), _spec((1,), i32))),
            (nm("attn_decode"), attn_decode,
             (_spec((1, d)), _spec((s, h, dh)), _spec((s, h, dh)),
              _spec((1,), i32))),
            (nm("gate_full"), gate, (_spec((s, d)),)),
            (nm("gate_one"), gate, (_spec((1, d)),)),
            (nm("moe_full"), moe, (_spec((s, d)), _spec((s, e)))),
            (nm("moe_one"), moe, (_spec((1, d)), _spec((1, e)))),
            (nm("moe_one_sparse"), moe_sparse,
             (_spec((1, d)), _spec((cap,), i32), _spec((cap,)))),
            # slot-batched decode artifacts (serving engine)
            (nm("attn_decode_batch"), attn_decode_batch,
             (_spec((bsl, d)), _spec((bsl, s, h, dh)),
              _spec((bsl, s, h, dh)), _spec((bsl,), i32))),
            (nm("gate_batch"), gate_batch, (_spec((bsl, d)),)),
            (nm("moe_batch_sparse"), moe_batch_sparse,
             (_spec((bsl, d)), _spec((bsl, cap), i32), _spec((bsl, cap)))),
        ]
    return entries


def lower_all(cfg: ModelConfig, out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    artifacts = {}
    for name, fn, specs in build_entries(cfg):
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        artifacts[name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [
                {"shape": list(sp.shape), "dtype": str(sp.dtype)}
                for sp in specs
            ],
            "hlo_chars": len(text),
        }
        print(f"  lowered {name}: {len(text)} chars")
    return artifacts


def write_manifest(cfg: ModelConfig, artifacts: dict, out_dir: str) -> None:
    manifest = {
        "model": cfg.manifest_dict(),
        "artifacts": artifacts,
        "format": "hlo-text/return-tuple",
    }
    path = os.path.join(out_dir, "manifest.json")
    with open(path, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"  wrote {path}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="output directory for .hlo.txt + manifest.json")
    ap.add_argument("--layers", type=int, default=None,
                    help="functional depth L (default: config's "
                         "n_layers_functional)")
    args = ap.parse_args()
    cfg = DEFAULT
    if args.layers is not None:
        if args.layers < 1:
            ap.error("--layers must be >= 1")
        cfg = dataclasses.replace(cfg, n_layers_functional=args.layers)
    print(f"AOT-lowering functional model {cfg}")
    artifacts = lower_all(cfg, args.out)
    write_manifest(cfg, artifacts, args.out)


if __name__ == "__main__":
    main()
