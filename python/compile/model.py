"""L2: the functional MoE transformer block (JAX, build-time only).

One Llama-style block — RMSNorm -> MHA (+residual) -> RMSNorm -> MoE
(+residual) — plus a toy embedding and an untied logits head, at the scaled-down
dims of config.ModelConfig.  The MoE expert FFNs and the gate MVM run through
the L1 Pallas kernels; attention/norms are plain jnp (digital units on the
paper's chip).

The block is exported as several *separately lowered* HLO executables
(aot.py) rather than one monolith, because the rust coordinator needs to
interleave its own logic between them: expert-choice routing, the GO cache's
TopKUpdate, KV-cache management and the PIM-simulator bookkeeping all live in
rust between `gate_*` and `moe_*` calls.

All weights are baked into the HLO as constants (seeded, reproducible); the
rust side passes activations only.
"""

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .kernels import ffn as kffn
from .kernels import gate as kgate
from .kernels import ref as kref


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig) -> dict:
    """Seeded model weights.  Scales follow 1/sqrt(fan_in) so activations
    stay O(1) through the quantised pipeline."""
    ks = jax.random.split(jax.random.PRNGKey(cfg.seed), 10)
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts

    def init(key, shape, fan_in):
        return (jax.random.normal(key, shape, dtype=jnp.float32)
                / jnp.sqrt(float(fan_in)))

    return {
        "embed": init(ks[0], (cfg.vocab, d), 1.0) * 0.5,
        "wq": init(ks[1], (d, d), d),
        "wk": init(ks[2], (d, d), d),
        "wv": init(ks[3], (d, d), d),
        "wo": init(ks[4], (d, d), d),
        "w_gate": init(ks[5], (d, e), d),
        "w_up": init(ks[6], (e, d, f), d),
        "w_down": init(ks[7], (e, f, d), f),
        "w_out": init(ks[8], (d, cfg.vocab), d),
        "norm_attn": jnp.ones((d,), dtype=jnp.float32),
        "norm_moe": jnp.ones((d,), dtype=jnp.float32),
    }


# ---------------------------------------------------------------------------
# Exported computations (each becomes one artifacts/<name>.hlo.txt)
# ---------------------------------------------------------------------------

def embed_tokens(params, cfg: ModelConfig, ids: jnp.ndarray):
    """ids [T] i32 -> x [T, D]."""
    return (jnp.take(params["embed"], ids, axis=0),)


def attn_prefill(params, cfg: ModelConfig, x: jnp.ndarray,
                 valid_len: jnp.ndarray):
    """Padded prefill attention.

    x [S, D], valid_len scalar i32 -> (h [S, D], k [S, H, Dh], v [S, H, Dh]).
    h includes the residual; rows >= valid_len are meaningless padding.
    """
    xn = kref.rmsnorm_ref(x, params["norm_attn"])
    out, k, v = kref.attention_prefill_ref(
        xn, params["wq"], params["wk"], params["wv"], params["wo"],
        cfg.n_heads, cfg.d_head, valid_len=valid_len)
    return x + out, k, v


def attn_decode(params, cfg: ModelConfig, x1: jnp.ndarray,
                k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                pos: jnp.ndarray):
    """One KV-cached decode step.

    x1 [1, D]; caches [S, H, Dh]; pos scalar i32 (index of the new token).
    Returns (h [1, D] with residual, k_new [1, H, Dh], v_new [1, H, Dh]).
    The rust coordinator owns the cache buffers and writes k_new/v_new back
    at `pos` (mirroring the DRAM-resident KV cache of the paper).
    """
    xn = kref.rmsnorm_ref(x1, params["norm_attn"])
    out, k_new, v_new = kref.attention_decode_ref(
        xn, k_cache, v_cache, pos, params["wq"], params["wk"], params["wv"],
        params["wo"], cfg.n_heads, cfg.d_head)
    return x1 + out, k_new, v_new


def gate_scores(params, cfg: ModelConfig, h: jnp.ndarray):
    """h [T, D] (post-attention hidden) -> raw gate scores [T, E].

    Runs the L1 digital-matmul Pallas kernel on the *normed* hidden state;
    routing (softmax + expert-choice top-k / TopKUpdate) happens in rust.
    """
    hn = kref.rmsnorm_ref(h, params["norm_moe"])
    return (kgate.gate_scores(hn, params["w_gate"]),)


def moe_apply(params, cfg: ModelConfig, h: jnp.ndarray, gates: jnp.ndarray):
    """h [T, D], gates [T, E] (dense mask from rust routing) -> y [T, D].

    y includes the residual: y = h + sum_e gates[:,e] * FFN_e(norm(h)).
    Every expert runs through the L1 crossbar kernels (dense-masked; the
    sparsity win is modelled by the L3 simulator).
    """
    hn = kref.rmsnorm_ref(h, params["norm_moe"])
    y = kffn.moe_apply(hn, gates, params["w_up"], params["w_down"],
                       xbar_rows=cfg.xbar_rows, dac_bits=cfg.dac_bits,
                       adc_bits=cfg.adc_bits,
                       range_factor=cfg.adc_range_factor)
    return (h + y,)


def moe_apply_sparse(params, cfg: ModelConfig, h: jnp.ndarray,
                     expert_idx: jnp.ndarray, gates: jnp.ndarray):
    """Sparse decode-path MoE (§Perf L2-1): h [1, D], expert_idx [K] i32,
    gates [K] f32 -> y [1, D] with y = h + sum_i gates[i] * FFN_{idx[i]}(h).

    The dense `moe_apply` computes *all* E experts and masks — fine for
    prefill batches, wasteful for one token that at most K experts
    selected.  This variant gathers the K selected experts' weights
    (jnp.take on the stacked tensors, the HLO analogue of addressing only
    the activated crossbars) and runs K pipelines instead of E.  Padding
    convention: unused slots carry gate 0.0 (their FFN output is computed
    but contributes exactly +0.0, keeping summation bit-compatible with
    the dense path's zero-gate terms).
    """
    hn = kref.rmsnorm_ref(h, params["norm_moe"])
    w_up = jnp.take(params["w_up"], expert_idx, axis=0)      # [K, D, F]
    w_down = jnp.take(params["w_down"], expert_idx, axis=0)  # [K, F, D]
    y = jnp.zeros_like(h)
    k = expert_idx.shape[0]
    for i in range(k):
        yi = kffn.expert_ffn(hn, w_up[i], w_down[i],
                             xbar_rows=cfg.xbar_rows, dac_bits=cfg.dac_bits,
                             adc_bits=cfg.adc_bits,
                             range_factor=cfg.adc_range_factor)
        y = y + gates[i] * yi
    return (h + y,)


# ---------------------------------------------------------------------------
# Slot-batched decode (serving path): one dispatch advances B live sessions
# ---------------------------------------------------------------------------
#
# Every batched computation below unrolls a python loop over the B slots at
# trace time, so the lowered HLO contains B copies of the *exact* single-token
# subgraph.  Per-row numerics are therefore bit-compatible with the
# corresponding `*_one` artifact run on that slot alone — the property the
# rust batched-vs-single equivalence test pins.  (The activation quantisers
# are per-row anyway — see kernels.ref.sym_quant — so no cross-slot coupling
# can sneak in through the analog pipeline either.)

def attn_decode_batch(params, cfg: ModelConfig, xb: jnp.ndarray,
                      k_caches: jnp.ndarray, v_caches: jnp.ndarray,
                      pos: jnp.ndarray):
    """Slot-batched KV-cached decode step.

    xb [B, D]; k_caches/v_caches [B, S, H, Dh] (the coordinator's pooled
    per-slot buffers, passed as one contiguous tensor); pos [B] i32.
    Returns (h [B, D], k_new [B, H, Dh], v_new [B, H, Dh]).
    """
    b = xb.shape[0]
    hs, ks, vs = [], [], []
    for i in range(b):
        h1, k1, v1 = attn_decode(params, cfg, xb[i:i + 1], k_caches[i],
                                 v_caches[i], pos[i])
        hs.append(h1)
        ks.append(k1)
        vs.append(v1)
    return (jnp.concatenate(hs, axis=0), jnp.concatenate(ks, axis=0),
            jnp.concatenate(vs, axis=0))


def gate_batch(params, cfg: ModelConfig, hb: jnp.ndarray):
    """hb [B, D] -> raw gate scores [B, E], one slot per row (unrolled)."""
    rows = [gate_scores(params, cfg, hb[i:i + 1])[0]
            for i in range(hb.shape[0])]
    return (jnp.concatenate(rows, axis=0),)


def moe_batch_sparse(params, cfg: ModelConfig, hb: jnp.ndarray,
                     expert_idx: jnp.ndarray, gates: jnp.ndarray):
    """Slot-batched sparse-gather MoE: hb [B, D], expert_idx [B, K] i32,
    gates [B, K] -> y [B, D] with row i = moe_apply_sparse on slot i.

    Padding convention per row matches the single-token artifact: unused
    slots carry gate 0.0 (their FFN output contributes exactly +0.0).
    """
    rows = [moe_apply_sparse(params, cfg, hb[i:i + 1], expert_idx[i],
                             gates[i])[0]
            for i in range(hb.shape[0])]
    return (jnp.concatenate(rows, axis=0),)


def logits(params, cfg: ModelConfig, h: jnp.ndarray):
    """h [1, D] -> logits [1, V] (untied head — a tied head makes the toy
    block parrot its input token, since the residual stream keeps the
    embedding; digital matmul)."""
    return (kgate.digital_matmul(h, params["w_out"]),)


# ---------------------------------------------------------------------------
# Whole-block reference (used by pytest, not exported)
# ---------------------------------------------------------------------------

def block_prefill_ref(params, cfg: ModelConfig, ids):
    """Full prefill at true length (no padding) for equivalence tests."""
    x = jnp.take(params["embed"], ids, axis=0)
    t = x.shape[0]
    h, k, v = attn_prefill(params, cfg, x, jnp.int32(t))
    scores = gate_scores(params, cfg, h)[0]
    gates = kref.expert_choice_gates_ref(scores, cfg.expert_capacity,
                                         valid_len=t)
    y = moe_apply(params, cfg, h, gates)[0]
    return y, scores, k, v
