"""L2: the functional MoE transformer block (JAX, build-time only).

One Llama-style block — RMSNorm -> MHA (+residual) -> RMSNorm -> MoE
(+residual) — plus a toy embedding and an untied logits head, at the scaled-down
dims of config.ModelConfig.  The MoE expert FFNs and the gate MVM run through
the L1 Pallas kernels; attention/norms are plain jnp (digital units on the
paper's chip).

The block is exported as several *separately lowered* HLO executables
(aot.py) rather than one monolith, because the rust coordinator needs to
interleave its own logic between them: expert-choice routing, the GO cache's
TopKUpdate, KV-cache management and the PIM-simulator bookkeeping all live in
rust between `gate_*` and `moe_*` calls.

All weights are baked into the HLO as constants (seeded, reproducible); the
rust side passes activations only.
"""

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .kernels import ffn as kffn
from .kernels import gate as kgate
from .kernels import ref as kref


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def _init(key, shape, fan_in):
    return (jax.random.normal(key, shape, dtype=jnp.float32)
            / jnp.sqrt(float(fan_in)))


def _layer_weights(cfg: ModelConfig, keys) -> dict:
    """Weights of one transformer block from 7 RNG keys.  Scales follow
    1/sqrt(fan_in) so activations stay O(1) through the quantised
    pipeline."""
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "wq": _init(keys[0], (d, d), d),
        "wk": _init(keys[1], (d, d), d),
        "wv": _init(keys[2], (d, d), d),
        "wo": _init(keys[3], (d, d), d),
        "w_gate": _init(keys[4], (d, e), d),
        "w_up": _init(keys[5], (e, d, f), d),
        "w_down": _init(keys[6], (e, f, d), f),
        "norm_attn": jnp.ones((d,), dtype=jnp.float32),
        "norm_moe": jnp.ones((d,), dtype=jnp.float32),
    }


def init_params(cfg: ModelConfig) -> dict:
    """Seeded model weights for a depth-`n_layers_functional` stack.

    Layer 0 draws from exactly the keys the single-block model used
    (ks[1..7] of the 10-way split), so an L=1 model is bit-identical to the
    pre-multi-layer one; layers >= 1 derive fresh keys via
    `fold_in(seed, layer)`.  Embedding and logits head are shared across
    the stack.
    """
    ks = jax.random.split(jax.random.PRNGKey(cfg.seed), 10)
    d = cfg.d_model
    layers = [_layer_weights(cfg, ks[1:8])]
    for layer in range(1, cfg.n_layers_functional):
        lks = jax.random.split(
            jax.random.fold_in(jax.random.PRNGKey(cfg.seed), layer), 7)
        layers.append(_layer_weights(cfg, lks))
    return {
        "embed": _init(ks[0], (cfg.vocab, d), 1.0) * 0.5,
        "w_out": _init(ks[8], (d, cfg.vocab), d),
        "layers": layers,
    }


# ---------------------------------------------------------------------------
# Exported computations (each becomes one artifacts/<name>.hlo.txt)
# ---------------------------------------------------------------------------

def embed_tokens(params, cfg: ModelConfig, ids: jnp.ndarray):
    """ids [T] i32 -> x [T, D]."""
    return (jnp.take(params["embed"], ids, axis=0),)


def attn_prefill(params, cfg: ModelConfig, x: jnp.ndarray,
                 valid_len: jnp.ndarray, layer: int = 0):
    """Padded prefill attention of one block.

    x [S, D], valid_len scalar i32 -> (h [S, D], k [S, H, Dh], v [S, H, Dh]).
    h includes the residual; rows >= valid_len are meaningless padding.
    """
    lp = params["layers"][layer]
    xn = kref.rmsnorm_ref(x, lp["norm_attn"])
    out, k, v = kref.attention_prefill_ref(
        xn, lp["wq"], lp["wk"], lp["wv"], lp["wo"],
        cfg.n_heads, cfg.d_head, valid_len=valid_len)
    return x + out, k, v


def attn_decode(params, cfg: ModelConfig, x1: jnp.ndarray,
                k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                pos: jnp.ndarray, layer: int = 0):
    """One KV-cached decode step of one block.

    x1 [1, D]; caches [S, H, Dh]; pos scalar i32 (index of the new token).
    Returns (h [1, D] with residual, k_new [1, H, Dh], v_new [1, H, Dh]).
    The rust coordinator owns the cache buffers (one bank per layer) and
    writes k_new/v_new back at `pos` (mirroring the DRAM-resident KV cache
    of the paper).
    """
    lp = params["layers"][layer]
    xn = kref.rmsnorm_ref(x1, lp["norm_attn"])
    out, k_new, v_new = kref.attention_decode_ref(
        xn, k_cache, v_cache, pos, lp["wq"], lp["wk"], lp["wv"],
        lp["wo"], cfg.n_heads, cfg.d_head)
    return x1 + out, k_new, v_new


def gate_scores(params, cfg: ModelConfig, h: jnp.ndarray, layer: int = 0):
    """h [T, D] (post-attention hidden) -> raw gate scores [T, E].

    Runs the L1 digital-matmul Pallas kernel on the *normed* hidden state;
    routing (softmax + expert-choice top-k / TopKUpdate) happens in rust.
    """
    lp = params["layers"][layer]
    hn = kref.rmsnorm_ref(h, lp["norm_moe"])
    return (kgate.gate_scores(hn, lp["w_gate"]),)


def moe_apply(params, cfg: ModelConfig, h: jnp.ndarray, gates: jnp.ndarray,
              layer: int = 0):
    """h [T, D], gates [T, E] (dense mask from rust routing) -> y [T, D].

    y includes the residual: y = h + sum_e gates[:,e] * FFN_e(norm(h)).
    Every expert runs through the L1 crossbar kernels (dense-masked; the
    sparsity win is modelled by the L3 simulator).
    """
    lp = params["layers"][layer]
    hn = kref.rmsnorm_ref(h, lp["norm_moe"])
    y = kffn.moe_apply(hn, gates, lp["w_up"], lp["w_down"],
                       xbar_rows=cfg.xbar_rows, dac_bits=cfg.dac_bits,
                       adc_bits=cfg.adc_bits,
                       range_factor=cfg.adc_range_factor)
    return (h + y,)


def moe_apply_sparse(params, cfg: ModelConfig, h: jnp.ndarray,
                     expert_idx: jnp.ndarray, gates: jnp.ndarray,
                     layer: int = 0):
    """Sparse decode-path MoE (§Perf L2-1): h [1, D], expert_idx [K] i32,
    gates [K] f32 -> y [1, D] with y = h + sum_i gates[i] * FFN_{idx[i]}(h).

    The dense `moe_apply` computes *all* E experts and masks — fine for
    prefill batches, wasteful for one token that at most K experts
    selected.  This variant gathers the K selected experts' weights
    (jnp.take on the stacked tensors, the HLO analogue of addressing only
    the activated crossbars) and runs K pipelines instead of E.  Padding
    convention: unused slots carry gate 0.0 (their FFN output is computed
    but contributes exactly +0.0, keeping summation bit-compatible with
    the dense path's zero-gate terms).
    """
    lp = params["layers"][layer]
    hn = kref.rmsnorm_ref(h, lp["norm_moe"])
    w_up = jnp.take(lp["w_up"], expert_idx, axis=0)      # [K, D, F]
    w_down = jnp.take(lp["w_down"], expert_idx, axis=0)  # [K, F, D]
    y = jnp.zeros_like(h)
    k = expert_idx.shape[0]
    for i in range(k):
        yi = kffn.expert_ffn(hn, w_up[i], w_down[i],
                             xbar_rows=cfg.xbar_rows, dac_bits=cfg.dac_bits,
                             adc_bits=cfg.adc_bits,
                             range_factor=cfg.adc_range_factor)
        y = y + gates[i] * yi
    return (h + y,)


# ---------------------------------------------------------------------------
# Slot-batched decode (serving path): one dispatch advances B live sessions
# ---------------------------------------------------------------------------
#
# Every batched computation below unrolls a python loop over the B slots at
# trace time, so the lowered HLO contains B copies of the *exact* single-token
# subgraph.  Per-row numerics are therefore bit-compatible with the
# corresponding `*_one` artifact run on that slot alone — the property the
# rust batched-vs-single equivalence test pins.  (The activation quantisers
# are per-row anyway — see kernels.ref.sym_quant — so no cross-slot coupling
# can sneak in through the analog pipeline either.)

def attn_decode_batch(params, cfg: ModelConfig, xb: jnp.ndarray,
                      k_caches: jnp.ndarray, v_caches: jnp.ndarray,
                      pos: jnp.ndarray, layer: int = 0):
    """Slot-batched KV-cached decode step of one block.

    xb [B, D]; k_caches/v_caches [B, S, H, Dh] (one contiguous layer bank
    of the coordinator's pooled per-slot buffers); pos [B] i32.
    Returns (h [B, D], k_new [B, H, Dh], v_new [B, H, Dh]).
    """
    b = xb.shape[0]
    hs, ks, vs = [], [], []
    for i in range(b):
        h1, k1, v1 = attn_decode(params, cfg, xb[i:i + 1], k_caches[i],
                                 v_caches[i], pos[i], layer=layer)
        hs.append(h1)
        ks.append(k1)
        vs.append(v1)
    return (jnp.concatenate(hs, axis=0), jnp.concatenate(ks, axis=0),
            jnp.concatenate(vs, axis=0))


def gate_batch(params, cfg: ModelConfig, hb: jnp.ndarray, layer: int = 0):
    """hb [B, D] -> raw gate scores [B, E], one slot per row (unrolled)."""
    rows = [gate_scores(params, cfg, hb[i:i + 1], layer=layer)[0]
            for i in range(hb.shape[0])]
    return (jnp.concatenate(rows, axis=0),)


def moe_batch_sparse(params, cfg: ModelConfig, hb: jnp.ndarray,
                     expert_idx: jnp.ndarray, gates: jnp.ndarray,
                     layer: int = 0):
    """Slot-batched sparse-gather MoE: hb [B, D], expert_idx [B, K] i32,
    gates [B, K] -> y [B, D] with row i = moe_apply_sparse on slot i.

    Padding convention per row matches the single-token artifact: unused
    slots carry gate 0.0 (their FFN output contributes exactly +0.0).
    """
    rows = [moe_apply_sparse(params, cfg, hb[i:i + 1], expert_idx[i],
                             gates[i], layer=layer)[0]
            for i in range(hb.shape[0])]
    return (jnp.concatenate(rows, axis=0),)


def logits(params, cfg: ModelConfig, h: jnp.ndarray):
    """h [1, D] -> logits [1, V] (untied head — a tied head makes the toy
    block parrot its input token, since the residual stream keeps the
    embedding; digital matmul)."""
    return (kgate.digital_matmul(h, params["w_out"]),)


# ---------------------------------------------------------------------------
# Whole-block reference (used by pytest, not exported)
# ---------------------------------------------------------------------------

def block_prefill_ref(params, cfg: ModelConfig, ids):
    """Full depth-L prefill at true length (no padding) for equivalence
    tests.  Returns the final hidden state plus per-layer scores/k/v
    lists (length `n_layers_functional`)."""
    x = jnp.take(params["embed"], ids, axis=0)
    t = x.shape[0]
    all_scores, all_k, all_v = [], [], []
    for layer in range(cfg.n_layers_functional):
        h, k, v = attn_prefill(params, cfg, x, jnp.int32(t), layer=layer)
        scores = gate_scores(params, cfg, h, layer=layer)[0]
        gates = kref.expert_choice_gates_ref(scores, cfg.expert_capacity,
                                             valid_len=t)
        x = moe_apply(params, cfg, h, gates, layer=layer)[0]
        all_scores.append(scores)
        all_k.append(k)
        all_v.append(v)
    return x, all_scores, all_k, all_v
