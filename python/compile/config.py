"""Model configuration for the functional (small-dims) MoE transformer block.

The paper's target model is Llama-MoE-4/16 (d_model=4096, 16 experts of
d_ff=688 each, top-4 expert-choice routing).  The operator-level simulator in
rust works analytically at those full dims; the *functional* path — the model
that is AOT-lowered to HLO and actually executed by the rust runtime — uses
the scaled-down dims below so that CPU-PJRT execution stays fast while the
dataflow (gate -> expert-choice -> grouping -> KV/GO caches) is exercised
end-to-end with real numerics.

Everything here is baked into the artifacts at `make artifacts` time and
recorded in artifacts/manifest.json, which the rust side reads.
"""

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Dims of the functional MoE transformer block."""

    d_model: int = 256        # hidden size (paper: 4096)
    n_experts: int = 16       # number of experts (paper: 16)
    top_k: int = 4            # experts activated per token (paper: 4)
    d_ff: int = 128           # per-expert FFN width (paper: 688 = 11008/16)
    n_heads: int = 4          # attention heads (paper: 32)
    d_head: int = 64          # head dim (paper: 128)
    vocab: int = 512          # toy vocabulary
    prompt_len: int = 32      # paper's prompt length
    max_seq: int = 96         # prompt + longest generation (paper: 32+64)
    batch_slots: int = 4      # serving batch width B (slot-batched decode)
    # Depth of the *functional* stack (paper model: 32 blocks).  Layer 0
    # reuses the exact seed weights of the single-block model, so L=1
    # artifacts (and their token streams) are bit-identical to the
    # pre-multi-layer ones; deeper layers derive fresh per-layer weights
    # from fold_in(seed, layer).
    n_layers_functional: int = 1
    seed: int = 20260710      # weight RNG seed

    # Crossbar-tiling parameters for the Pallas kernels.  The paper's chip is
    # a 256x256 HERMES crossbar with 8-bit I/O; at d_model=256 we tile with
    # 128x128 blocks (two row-tiles per matrix) so the kernel exercises the
    # same multi-tile accumulate + per-slice ADC path that full dims would.
    xbar_rows: int = 128
    xbar_cols: int = 128
    adc_bits: int = 8         # ADC resolution (per-slice partial-sum readout)
    dac_bits: int = 8         # DAC input resolution
    # Per-column ADC ranging factor (HERMES calibrates its CCO ADCs to the
    # observed signal distribution; see kernels.ref.adc_step).
    adc_range_factor: float = 16.0

    @property
    def expert_capacity(self) -> int:
        """Tokens each expert selects during prefill (expert-choice routing).

        capacity = prompt_len * top_k / n_experts, the load-balanced value
        from Zhou et al. [12]; the paper keeps it fixed during generation so
        the GO output cache stays at a static k x E x d size.
        """
        return self.prompt_len * self.top_k // self.n_experts

    @property
    def expert_capacity_per_layer(self) -> list:
        """Per-layer expert capacity (recorded in the manifest so the rust
        side sizes each layer's GO bank independently).  Uniform today —
        every layer routes at the load-balanced prefill capacity — but the
        schema supports heterogeneous depth-wise capacities."""
        return [self.expert_capacity] * self.n_layers_functional

    def manifest_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["expert_capacity"] = self.expert_capacity
        d["expert_capacity_per_layer"] = self.expert_capacity_per_layer
        return d


DEFAULT = ModelConfig()
