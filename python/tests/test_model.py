"""L2 model-level tests: shapes, padding equivalence, KV-decode equivalence.

These pin the contract the rust coordinator relies on: padded prefill agrees
with unpadded prefill on valid rows, and a KV-cached decode step reproduces
what a full (re-)prefill would compute for the last token.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.config import ModelConfig
from compile.kernels import ref


def toks(cfg, n, seed=0):
    return jax.random.randint(jax.random.PRNGKey(seed), (n,), 0, cfg.vocab)


# ---------------------------------------------------------------------------
# Shapes of every exported computation
# ---------------------------------------------------------------------------

def test_export_shapes(cfg, params):
    s, d, e, v = cfg.max_seq, cfg.d_model, cfg.n_experts, cfg.vocab
    h, dh = cfg.n_heads, cfg.d_head
    ids = toks(cfg, s)
    (x,) = model.embed_tokens(params, cfg, ids)
    assert x.shape == (s, d)
    hh, k, vv = model.attn_prefill(params, cfg, x, jnp.int32(32))
    assert hh.shape == (s, d) and k.shape == (s, h, dh) and vv.shape == (s, h, dh)
    (scores,) = model.gate_scores(params, cfg, hh)
    assert scores.shape == (s, e)
    gates = ref.expert_choice_gates_ref(scores, cfg.expert_capacity,
                                        valid_len=32)
    (y,) = model.moe_apply(params, cfg, hh, gates)
    assert y.shape == (s, d)
    h1, k1, v1 = model.attn_decode(params, cfg, x[:1], k, vv, jnp.int32(32))
    assert h1.shape == (1, d) and k1.shape == (1, h, dh)
    (lg,) = model.logits(params, cfg, y[:1])
    assert lg.shape == (1, v)


def test_embed_deterministic(cfg, params):
    ids = toks(cfg, cfg.max_seq, seed=3)
    a = model.embed_tokens(params, cfg, ids)[0]
    b = model.embed_tokens(params, cfg, ids)[0]
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Padding equivalence
# ---------------------------------------------------------------------------

def test_padded_prefill_matches_unpadded(tiny_cfg, tiny_params):
    cfg, params = tiny_cfg, tiny_params
    t = cfg.prompt_len
    ids = toks(cfg, t, seed=1)
    # unpadded: exact length
    x = jnp.take(params["embed"], ids, axis=0)
    h_u, k_u, v_u = model.attn_prefill(params, cfg, x, jnp.int32(t))
    # padded to max_seq with junk tokens
    ids_pad = jnp.concatenate([ids, toks(cfg, cfg.max_seq - t, seed=99)])
    x_pad = jnp.take(params["embed"], ids_pad, axis=0)
    h_p, k_p, v_p = model.attn_prefill(params, cfg, x_pad, jnp.int32(t))
    np.testing.assert_allclose(np.asarray(h_p[:t]), np.asarray(h_u[:t]),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(k_p[:t]), np.asarray(k_u[:t]),
                               rtol=1e-5, atol=1e-5)


def test_gate_scores_row_local(cfg, params):
    """Gate scores for row i depend only on row i (no cross-token leakage),
    so the 1-token gate executable agrees with the full one — the identity
    that makes the GO cache sound."""
    s = cfg.max_seq
    h = jax.random.normal(jax.random.PRNGKey(5), (s, cfg.d_model))
    full = model.gate_scores(params, cfg, h)[0]
    one = model.gate_scores(params, cfg, h[7:8])[0]
    np.testing.assert_allclose(np.asarray(full[7:8]), np.asarray(one),
                               rtol=1e-5, atol=1e-5)


def test_moe_apply_row_local(cfg, params):
    h = jax.random.normal(jax.random.PRNGKey(6), (cfg.max_seq, cfg.d_model))
    gates = jax.nn.softmax(
        jax.random.normal(jax.random.PRNGKey(7), (cfg.max_seq, cfg.n_experts)))
    full = model.moe_apply(params, cfg, h, gates)[0]
    one = model.moe_apply(params, cfg, h[3:4], gates[3:4])[0]
    # per-row DAC ranging makes the quantised pipeline row-local, so the
    # 1-token executable reproduces the batch row up to dequant-scale ulps
    np.testing.assert_allclose(np.asarray(full[3:4]), np.asarray(one),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# KV-cached decode == recompute
# ---------------------------------------------------------------------------

def test_decode_step_matches_prefill(tiny_cfg, tiny_params):
    """Prefill t+1 tokens vs prefill t then one cached decode step: the last
    token's hidden state must agree."""
    cfg, params = tiny_cfg, tiny_params
    t = cfg.prompt_len
    ids = toks(cfg, t + 1, seed=2)
    x_all = jnp.take(params["embed"], ids, axis=0)

    # full prefill over t+1
    pad = jnp.zeros((cfg.max_seq - (t + 1), cfg.d_model))
    x_pad = jnp.concatenate([x_all, pad])
    h_full, _, _ = model.attn_prefill(params, cfg, x_pad, jnp.int32(t + 1))

    # prefill t, then decode token t with the KV cache
    x_pad_t = jnp.concatenate([x_all[:t],
                               jnp.zeros((cfg.max_seq - t, cfg.d_model))])
    _, k, v = model.attn_prefill(params, cfg, x_pad_t, jnp.int32(t))
    h_dec, k1, v1 = model.attn_decode(params, cfg, x_all[t:t + 1], k, v,
                                      jnp.int32(t))
    np.testing.assert_allclose(np.asarray(h_dec), np.asarray(h_full[t:t + 1]),
                               rtol=1e-4, atol=1e-4)
    # and the K/V written back equal the prefill's row t
    _, k_ref, v_ref = model.attn_prefill(params, cfg, x_pad, jnp.int32(t + 1))
    np.testing.assert_allclose(np.asarray(k1[0]), np.asarray(k_ref[t]),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(v1[0]), np.asarray(v_ref[t]),
                               rtol=1e-4, atol=1e-4)


def test_block_prefill_ref_runs(tiny_cfg, tiny_params):
    y, scores, k, v = model.block_prefill_ref(tiny_params, tiny_cfg,
                                              toks(tiny_cfg,
                                                   tiny_cfg.prompt_len))
    assert y.shape == (tiny_cfg.prompt_len, tiny_cfg.d_model)
    assert len(scores) == tiny_cfg.n_layers_functional
    assert scores[0].shape == (tiny_cfg.prompt_len, tiny_cfg.n_experts)
    assert np.isfinite(np.asarray(y)).all()


# ---------------------------------------------------------------------------
# Numerical sanity of the quantised block
# ---------------------------------------------------------------------------

def test_activations_bounded(cfg, params):
    """Residual stream stays O(1)-ish through the quantised MoE (no analog
    blow-up), a prerequisite for multi-step generation."""
    ids = toks(cfg, cfg.max_seq, seed=8)
    (x,) = model.embed_tokens(params, cfg, ids)
    h, _, _ = model.attn_prefill(params, cfg, x, jnp.int32(cfg.prompt_len))
    scores = model.gate_scores(params, cfg, h)[0]
    gates = ref.expert_choice_gates_ref(scores, cfg.expert_capacity,
                                        valid_len=cfg.prompt_len)
    (y,) = model.moe_apply(params, cfg, h, gates)
    assert float(jnp.max(jnp.abs(y[:cfg.prompt_len]))) < 50.0


def test_init_params_seeded(cfg):
    a = model.init_params(cfg)
    b = model.init_params(cfg)
    np.testing.assert_array_equal(np.asarray(a["layers"][0]["w_up"]),
                                  np.asarray(b["layers"][0]["w_up"]))


def test_deeper_layers_get_distinct_weights():
    """Layer 0 of a deep stack must equal the single-block weights (the
    L=1 bit-identity contract) while layers >= 1 draw fresh weights."""
    shallow = ModelConfig(d_model=64, n_experts=4, top_k=2, d_ff=32,
                          n_heads=2, d_head=32, vocab=64, prompt_len=8,
                          max_seq=16)
    import dataclasses
    deep_cfg = dataclasses.replace(shallow, n_layers_functional=3)
    p1 = model.init_params(shallow)
    p3 = model.init_params(deep_cfg)
    assert len(p3["layers"]) == 3
    np.testing.assert_array_equal(np.asarray(p1["layers"][0]["w_up"]),
                                  np.asarray(p3["layers"][0]["w_up"]))
    np.testing.assert_array_equal(np.asarray(p1["embed"]),
                                  np.asarray(p3["embed"]))
    assert not np.array_equal(np.asarray(p3["layers"][0]["w_up"]),
                              np.asarray(p3["layers"][1]["w_up"]))
    assert not np.array_equal(np.asarray(p3["layers"][1]["w_up"]),
                              np.asarray(p3["layers"][2]["w_up"]))
