"""AOT path tests: lowering produces parseable, constant-complete HLO text
and a manifest the rust config layer can consume."""

import json
import os
import tempfile

import pytest

from compile import aot
from compile.config import ModelConfig


@pytest.fixture(scope="module")
def lowered_dir():
    """Lower a tiny config once for all tests in this module."""
    cfg = ModelConfig(d_model=128, n_experts=4, top_k=2, d_ff=128,
                      n_heads=2, d_head=64, vocab=64, prompt_len=8,
                      max_seq=16)
    with tempfile.TemporaryDirectory() as d:
        artifacts = aot.lower_all(cfg, d)
        aot.write_manifest(cfg, artifacts, d)
        yield cfg, d, artifacts


def test_all_entries_lowered(lowered_dir):
    cfg, d, artifacts = lowered_dir
    names = {name for name, _, _ in aot.build_entries(cfg)}
    assert set(artifacts) == names
    for meta in artifacts.values():
        assert os.path.exists(os.path.join(d, meta["file"]))


def test_no_elided_constants(lowered_dir):
    """'{...}' in HLO text means a weight constant was elided — it would
    parse back as garbage on the rust side."""
    cfg, d, artifacts = lowered_dir
    for meta in artifacts.values():
        text = open(os.path.join(d, meta["file"])).read()
        assert "{...}" not in text, f"{meta['file']} has elided constants"


def test_hlo_text_is_module(lowered_dir):
    cfg, d, artifacts = lowered_dir
    for meta in artifacts.values():
        text = open(os.path.join(d, meta["file"])).read()
        assert text.startswith("HloModule"), meta["file"]
        assert "ROOT" in text


def test_entry_layout_matches_manifest(lowered_dir):
    """The manifest's input table must agree with the HLO entry layout —
    the rust runtime trusts it when staging literals."""
    cfg, d, artifacts = lowered_dir
    for name, meta in artifacts.items():
        text = open(os.path.join(d, meta["file"])).read()
        header = text.splitlines()[0]
        assert "entry_computation_layout" in header
        for inp in meta["inputs"]:
            dims = ",".join(str(x) for x in inp["shape"])
            ty = "s32" if inp["dtype"] == "int32" else "f32"
            assert f"{ty}[{dims}]" in header, (name, inp, header)


def test_manifest_contents(lowered_dir):
    cfg, d, artifacts = lowered_dir
    m = json.load(open(os.path.join(d, "manifest.json")))
    assert m["format"] == "hlo-text/return-tuple"
    assert m["model"]["d_model"] == cfg.d_model
    assert m["model"]["expert_capacity"] == cfg.expert_capacity
    assert set(m["artifacts"]) == set(artifacts)


def test_layered_lowering_names_and_manifest():
    """Depth L > 1: per-block families are lowered once per layer with the
    `_l{layer}` suffix (layer 0 bare), shared entries stay single, and the
    manifest records the depth + per-layer capacities."""
    cfg = ModelConfig(d_model=128, n_experts=4, top_k=2, d_ff=128,
                      n_heads=2, d_head=64, vocab=64, prompt_len=8,
                      max_seq=16, n_layers_functional=2)
    names = [name for name, _, _ in aot.build_entries(cfg)]
    assert names.count("gate_one") == 1
    assert "gate_one_l1" in names and "gate_one_l2" not in names
    assert "attn_decode_batch_l1" in names
    assert names.count("embed_batch") == 1 and "embed_batch_l1" not in names
    assert "logits_one_l1" not in names
    # 4 shared + 10 per-block families per layer
    assert len(names) == 4 + 10 * 2

    m = cfg.manifest_dict()
    assert m["n_layers_functional"] == 2
    assert m["expert_capacity_per_layer"] == [cfg.expert_capacity] * 2

    with tempfile.TemporaryDirectory() as d:
        artifacts = aot.lower_all(cfg, d)
        aot.write_manifest(cfg, artifacts, d)
        manifest = json.load(open(os.path.join(d, "manifest.json")))
        assert manifest["model"]["n_layers_functional"] == 2
        assert set(artifacts) == set(names)
        l0 = open(os.path.join(d, "gate_one.hlo.txt")).read()
        l1 = open(os.path.join(d, "gate_one_l1.hlo.txt")).read()
        assert l0 != l1, "layers must bake distinct weights"


def test_outputs_are_tuples(lowered_dir):
    """return_tuple=True: every ROOT is a tuple so the rust side can always
    unwrap with to_tupleN."""
    cfg, d, artifacts = lowered_dir
    for meta in artifacts.values():
        header = open(os.path.join(d, meta["file"])).read().splitlines()[0]
        # entry layout prints ->(...) for tuple returns
        assert "->(" in header.replace(" ", ""), meta["file"]
