"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

The crossbar kernel and its oracle share every arithmetic step with exact
integer partial sums (representable in f32), so accumulations match
bit-for-bit; only the final dequant scaling may differ by 1 ulp (XLA
reassociates the scalar multiply between modules), hence tight-allclose
rather than array_equal.  Hypothesis sweeps shapes/seeds/bit-widths.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import crossbar, ffn, gate, ref
from quant_tol import assert_close_quant, crossbar_lsb

hypothesis.settings.register_profile(
    "kernels", max_examples=25, deadline=None,
    suppress_health_check=[hypothesis.HealthCheck.too_slow])
hypothesis.settings.load_profile("kernels")


def rand(key, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape,
                                     dtype=jnp.float32)


# ---------------------------------------------------------------------------
# crossbar_matmul vs oracle
# ---------------------------------------------------------------------------

@hypothesis.given(
    m=st.sampled_from([1, 2, 8, 32, 96]),
    k_tiles=st.integers(1, 4),
    n=st.sampled_from([16, 128, 256]),
    xbar_rows=st.sampled_from([64, 128]),
    seed=st.integers(0, 2**16),
)
def test_crossbar_matches_oracle(m, k_tiles, n, xbar_rows, seed):
    k = k_tiles * xbar_rows
    x = rand(seed, (m, k))
    w = rand(seed + 1, (k, n))
    got = crossbar.crossbar_matmul(x, w, xbar_rows=xbar_rows)
    want = ref.crossbar_matmul_ref(x, w, xbar_rows=xbar_rows)
    # equal within one quantisation LSB (see quant_tol docstring)
    assert_close_quant(got, want, crossbar_lsb(x, w, xbar_rows=xbar_rows))


@hypothesis.given(
    dac_bits=st.sampled_from([4, 6, 8]),
    adc_bits=st.sampled_from([4, 6, 8, 10]),
    range_factor=st.sampled_from([1.0, 8.0, 32.0, 128.0]),
    seed=st.integers(0, 2**16),
)
def test_crossbar_bitwidth_sweep(dac_bits, adc_bits, range_factor, seed):
    x = rand(seed, (8, 256))
    w = rand(seed + 7, (256, 128))
    got = crossbar.crossbar_matmul(x, w, xbar_rows=128, dac_bits=dac_bits,
                                   adc_bits=adc_bits,
                                   range_factor=range_factor)
    want = ref.crossbar_matmul_ref(x, w, xbar_rows=128, dac_bits=dac_bits,
                                   adc_bits=adc_bits,
                                   range_factor=range_factor)
    assert_close_quant(got, want,
                       crossbar_lsb(x, w, xbar_rows=128, dac_bits=dac_bits,
                                    adc_bits=adc_bits,
                                    range_factor=range_factor))


def test_crossbar_accuracy_vs_exact():
    """The emulated analog pipeline must stay within a few percent of the
    exact product at the paper's 8-bit I/O spec (ranged ADC)."""
    x = rand(3, (32, 256))
    w = rand(4, (256, 128))
    got = crossbar.crossbar_matmul(x, w, xbar_rows=128)
    exact = x @ w
    rel = float(jnp.max(jnp.abs(got - exact)) / jnp.max(jnp.abs(exact)))
    assert rel < 0.05, f"quantisation error too large: {rel}"


def test_crossbar_zero_input():
    x = jnp.zeros((4, 256))
    w = rand(5, (256, 128))
    got = crossbar.crossbar_matmul(x, w, xbar_rows=128)
    np.testing.assert_array_equal(np.asarray(got), np.zeros((4, 128)))


def test_crossbar_rejects_bad_k():
    with pytest.raises(AssertionError):
        crossbar.crossbar_matmul(rand(0, (4, 100)), rand(1, (100, 16)),
                                 xbar_rows=128)


def test_adc_step_monotone_in_bits():
    """More ADC bits -> finer grid."""
    steps = [ref.adc_step(128, 8, b, 32.0) for b in (4, 6, 8, 10)]
    assert all(a > b for a, b in zip(steps, steps[1:]))


def test_sym_quant_roundtrip_bound():
    x = rand(11, (64, 64), scale=3.0)
    q, s = ref.sym_quant(x, 8)
    assert float(jnp.max(jnp.abs(q))) <= 127.0
    err = float(jnp.max(jnp.abs(q * s - x)))
    assert err <= float(s) * 0.5 + 1e-6


def test_sym_quant_all_zero():
    q, s = ref.sym_quant(jnp.zeros((4, 4)), 8)
    np.testing.assert_array_equal(np.asarray(q), np.zeros((4, 4)))
    assert float(s) == 1.0


# ---------------------------------------------------------------------------
# digital matmul vs oracle
# ---------------------------------------------------------------------------

@hypothesis.given(
    m=st.sampled_from([1, 3, 32, 96]),
    k=st.sampled_from([128, 256]),
    n=st.sampled_from([16, 128, 512]),
    seed=st.integers(0, 2**16),
)
def test_digital_matmul_matches_oracle(m, k, n, seed):
    x = rand(seed, (m, k))
    w = rand(seed + 3, (k, n))
    got = gate.digital_matmul(x, w)
    want = ref.matmul_ref(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


def test_pick_tile():
    assert crossbar._pick_tile(96, 32) == 32
    assert crossbar._pick_tile(1, 32) == 1
    assert crossbar._pick_tile(16, 128) == 16
    assert crossbar._pick_tile(256, 128) == 128
    # non power-of-two dim falls back to a divisor
    assert 96 % crossbar._pick_tile(96, 64) == 0


# ---------------------------------------------------------------------------
# expert FFN and MoE apply vs oracle
# ---------------------------------------------------------------------------

@hypothesis.given(
    m=st.sampled_from([1, 8, 32]),
    seed=st.integers(0, 2**16),
)
def test_expert_ffn_matches_oracle(m, seed):
    x = rand(seed, (m, 256))
    w_up = rand(seed + 1, (256, 128), scale=1 / 16)
    w_down = rand(seed + 2, (128, 256), scale=1 / 11)
    got = ffn.expert_ffn(x, w_up, w_down, xbar_rows=128)
    want = ref.expert_ffn_ref(x, w_up, w_down, xbar_rows=128)
    # two quantisation stages; tolerance from the second stage's LSB
    h = ref.expert_ffn_ref(x, w_up, w_down, xbar_rows=128)  # for ranging
    assert_close_quant(got, want, crossbar_lsb(h, w_down, xbar_rows=128))


@hypothesis.given(seed=st.integers(0, 2**16))
@hypothesis.settings(max_examples=5, deadline=None)
def test_moe_apply_matches_oracle(seed):
    e, d, f, t = 4, 256, 128, 8
    x = rand(seed, (t, d))
    w_up = rand(seed + 1, (e, d, f), scale=1 / 16)
    w_down = rand(seed + 2, (e, f, d), scale=1 / 11)
    gates = jax.nn.softmax(rand(seed + 3, (t, e)))
    got = ffn.moe_apply(x, gates, w_up, w_down, xbar_rows=128)
    want = ref.moe_apply_ref(x, gates, w_up, w_down, xbar_rows=128)
    lsb = sum(crossbar_lsb(x, w_down[i], xbar_rows=128) for i in range(e))
    assert_close_quant(got, want, lsb)


def test_moe_apply_zero_gates_is_zero():
    e, d, f, t = 4, 256, 128, 4
    x = rand(0, (t, d))
    w_up = rand(1, (e, d, f))
    w_down = rand(2, (e, f, d))
    got = ffn.moe_apply(x, jnp.zeros((t, e)), w_up, w_down, xbar_rows=128)
    np.testing.assert_array_equal(np.asarray(got), np.zeros((t, d)))


def test_moe_apply_single_expert_equals_ffn():
    d, f, t = 256, 128, 4
    x = rand(3, (t, d))
    w_up = rand(4, (1, d, f))
    w_down = rand(5, (1, f, d))
    gates = jnp.ones((t, 1))
    got = ffn.moe_apply(x, gates, w_up, w_down, xbar_rows=128)
    want = ffn.expert_ffn(x, w_up[0], w_down[0], xbar_rows=128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_noisy_readout_statistics():
    """Analog read noise (paper future-work axis): zero noise is exact;
    higher noise raises output error monotonically."""
    x = rand(21, (16, 256))
    w = rand(22, (256, 128))
    key = jax.random.PRNGKey(0)
    clean = ref.crossbar_matmul_ref(x, w, xbar_rows=128)

    def noisy(std):
        qx, sx = ref.sym_quant(x, 8, axis=-1)
        qw, sw = ref.sym_quant(w, 8)
        acc = jnp.zeros((16, 128))
        for s_ in range(2):
            part = qx[:, s_ * 128:(s_ + 1) * 128] @ qw[s_ * 128:(s_ + 1) * 128]
            acc = acc + ref.adc_readout(part, 128, 8, 8, noise_std=std,
                                        noise_key=jax.random.fold_in(key, s_))
        return acc * (sx * sw)

    e0 = float(jnp.max(jnp.abs(noisy(0.0) - clean)))
    e1 = float(jnp.mean(jnp.abs(noisy(0.5) - clean)))
    e2 = float(jnp.mean(jnp.abs(noisy(2.0) - clean)))
    assert e0 == 0.0
    assert e2 > e1 > 0.0


def test_vmem_budget():
    """The full-dims tiling (256x256 blocks) must fit comfortably in a
    16 MiB VMEM with double buffering — the §Perf structural check."""
    per_cell = crossbar.vmem_bytes(tile_m=32, tile_n=256, xbar_rows=256)
    assert 2 * per_cell < 16 * 1024 * 1024
