"""Shared pytest fixtures for the kernel/model suites."""

import pytest

from compile.config import ModelConfig
from compile import model


@pytest.fixture(scope="session")
def cfg():
    return ModelConfig()


@pytest.fixture(scope="session")
def tiny_cfg():
    """A smaller block for the expensive whole-model equivalence tests."""
    return ModelConfig(d_model=128, n_experts=8, top_k=2, d_ff=128,
                       n_heads=2, d_head=64, vocab=64, prompt_len=8,
                       max_seq=16)


@pytest.fixture(scope="session")
def params(cfg):
    return model.init_params(cfg)


@pytest.fixture(scope="session")
def tiny_params(tiny_cfg):
    return model.init_params(tiny_cfg)
