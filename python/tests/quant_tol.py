"""Tolerance helper for kernel-vs-oracle comparisons.

The kernel and oracle perform identical arithmetic, but they live in
*separately jitted* XLA modules: scalar constants (quant scales, their
products) may be fused/reassociated differently, giving 1-ulp input
differences.  Near a .5 boundary a 1-ulp difference flips a round(), which
moves the result by exactly one quantisation step.  The honest contract is
therefore "equal within one LSB of each quantisation stage", computed here
in output units.
"""

import jax.numpy as jnp
import numpy as np

from compile.kernels import ref


def crossbar_lsb(x, w, *, xbar_rows, dac_bits=8, adc_bits=8,
                 range_factor=16.0) -> float:
    """One worst-case LSB of crossbar_matmul's output units: a flipped DAC
    round (±1 input level -> ±qmax_w per slice partial, then possibly one
    ADC step per slice) or a flipped ADC round (one step)."""
    step = ref.adc_step(xbar_rows, dac_bits, adc_bits, range_factor)
    _, sx = ref.sym_quant(x, dac_bits, axis=-1)
    _, sw = ref.sym_quant(w, dac_bits)
    n_slices = x.shape[-1] // xbar_rows
    return float(step * jnp.max(sx) * sw) * n_slices


def assert_close_quant(got, want, lsb: float, rtol: float = 1e-5):
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=rtol, atol=1.01 * lsb)
