"""Expert-choice routing and GO-cache (TopKUpdate) math-level oracles.

These pin the *semantics* the rust coordinator re-implements: the rust
proptest suites in rust/tests/ check the same invariants against the rust
code; here we check them against the jnp oracle so the two sides agree on a
single definition (earlier-token-wins tie-break, fixed capacity, streaming
top-k == batch top-k).
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np

from compile.kernels import ref

hypothesis.settings.register_profile("routing", max_examples=50,
                                     deadline=None)
hypothesis.settings.load_profile("routing")


def scores_for(seed, t, e):
    return jax.random.normal(jax.random.PRNGKey(seed), (t, e),
                             dtype=jnp.float32)


# ---------------------------------------------------------------------------
# expert_choice_gates_ref invariants
# ---------------------------------------------------------------------------

@hypothesis.given(
    t=st.integers(4, 64),
    e=st.sampled_from([4, 8, 16]),
    cap=st.integers(1, 8),
    seed=st.integers(0, 2**16),
)
def test_each_expert_selects_exactly_capacity(t, e, cap, seed):
    cap = min(cap, t)
    gates = ref.expert_choice_gates_ref(scores_for(seed, t, e), cap)
    per_expert = np.asarray((gates > 0).sum(axis=0))
    np.testing.assert_array_equal(per_expert, np.full(e, cap))


@hypothesis.given(
    t=st.integers(8, 64),
    valid=st.integers(1, 8),
    seed=st.integers(0, 2**16),
)
def test_padded_tokens_never_selected(t, valid, seed):
    e, cap = 8, 1
    valid = min(valid, t)
    gates = ref.expert_choice_gates_ref(scores_for(seed, t, e), cap,
                                        valid_len=valid)
    sel = np.asarray(gates > 0)
    assert not sel[valid:].any(), "padding rows must receive no experts"
    assert sel[:valid].sum() == e * cap


@hypothesis.given(seed=st.integers(0, 2**16))
def test_gate_values_are_softmax_probs(seed):
    t, e, cap = 16, 8, 4
    s = scores_for(seed, t, e)
    gates = ref.expert_choice_gates_ref(s, cap)
    probs = np.asarray(jax.nn.softmax(s, axis=-1))
    g = np.asarray(gates)
    sel = g > 0
    np.testing.assert_allclose(g[sel], probs[sel], rtol=1e-6)


def test_capacity_equals_token_count_selects_all():
    t, e = 8, 4
    gates = ref.expert_choice_gates_ref(scores_for(0, t, e), t)
    assert bool((np.asarray(gates) > 0).all())


# ---------------------------------------------------------------------------
# Streaming TopKUpdate == batch top-k  (Eq. 4-5)
# ---------------------------------------------------------------------------

def batch_topk_sets(scores: np.ndarray, cap: int):
    """Selected-token sets per expert from a full batch top-k over the
    softmax probs (Zhou et al. rank S = softmax(X Wg) per expert column;
    stable: earlier token wins ties)."""
    scores = np.asarray(jax.nn.softmax(jnp.asarray(scores), axis=-1))
    t, e = scores.shape
    sets = []
    for j in range(e):
        order = np.argsort(-scores[:, j], kind="stable")
        sets.append(set(order[:cap].tolist()))
    return sets


def streaming_topk_sets(scores: np.ndarray, cap: int, prefix: int):
    """Seed with the first `prefix` tokens (batch), then TopKUpdate one
    token at a time — the GO-cache procedure during generation."""
    t, e = scores.shape
    probs = np.asarray(jax.nn.softmax(jnp.asarray(scores), axis=-1))
    sets = batch_topk_sets(scores[:prefix], cap)
    scores = probs  # the cache stores/compares softmaxed scores
    # per-expert min-score threshold tracking, as the GO cache does
    for tok in range(prefix, t):
        for j in range(e):
            cached = sorted(sets[j], key=lambda i: (-scores[i, j], i))
            worst = cached[-1]
            s_new, s_worst = scores[tok, j], scores[worst, j]
            # Eq. 5: replace iff s_new >= min(S_prev); tie keeps the earlier
            # token (strict > on equal scores keeps `worst`, which is
            # earlier than `tok`).
            if s_new > s_worst:
                sets[j] = (sets[j] - {worst}) | {tok}
    return sets


@hypothesis.given(
    t=st.integers(6, 48),
    e=st.sampled_from([4, 8, 16]),
    cap=st.integers(1, 6),
    prefix=st.integers(4, 16),
    seed=st.integers(0, 2**16),
)
def test_streaming_equals_batch(t, e, cap, prefix, seed):
    prefix = min(prefix, t)
    cap = min(cap, prefix)
    scores = np.asarray(scores_for(seed, t, e))
    assert streaming_topk_sets(scores, cap, prefix) == \
        batch_topk_sets(scores, cap)


def test_streaming_equals_batch_with_ties():
    scores = np.zeros((10, 3), dtype=np.float32)  # all ties
    assert streaming_topk_sets(scores, 4, 5) == batch_topk_sets(scores, 4)


@hypothesis.given(seed=st.integers(0, 2**16))
def test_at_most_one_change_per_expert_per_step(seed):
    """Paper §III-C: 'each generation step will result in at most one change
    per expert' — the property that bounds GO output-cache DRAM traffic."""
    t, e, cap, prefix = 20, 8, 4, 8
    scores = np.asarray(scores_for(seed, t, e))
    sets = batch_topk_sets(scores[:prefix], cap)
    for tok in range(prefix, t):
        nxt = streaming_topk_sets(scores[:tok + 1], cap, prefix)
        for j in range(e):
            assert len(sets[j] - nxt[j]) <= 1
            assert len(nxt[j] - sets[j]) <= 1
        sets = nxt


def test_gates_match_streaming_selection():
    """Dense-mask routing and the streaming set view agree."""
    t, e, cap = 12, 4, 3
    s = scores_for(9, t, e)
    gates = np.asarray(ref.expert_choice_gates_ref(s, cap))
    sets = batch_topk_sets(np.asarray(s), cap)
    for j in range(e):
        sel = set(np.nonzero(gates[:, j])[0].tolist())
        assert sel == sets[j]
