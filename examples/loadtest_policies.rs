//! E8 driver: admission-policy comparison under identical seeded traffic
//! on the virtual-time cluster — no artifacts needed, byte-reproducible.
//!
//! For each arrival shape (steady Poisson vs bursty on/off), the same
//! materialized request stream is served under FIFO, SJF, and EDF
//! admission, and the headline SLO metrics are tabulated: because the
//! traffic, the routing trajectories, and the planner's contention model
//! are all seeded, any difference between rows is the policy and nothing
//! else.
//!
//! ```bash
//! cargo run --release --example loadtest_policies
//! ```

use moepim::workload::report;
use moepim::workload::{
    run_virtual, AdmissionPolicy, ArrivalProcess, SizeModel, VirtualConfig,
    WorkloadSpec,
};

fn spec(arrival: ArrivalProcess) -> WorkloadSpec {
    WorkloadSpec {
        seed: 7,
        requests: 96,
        arrival,
        sizes: SizeModel::TraceSeeded {
            n_experts: 16,
            skew: 1.2,
            prompt: (4, 24),
            gen: (1, 12),
        },
        slo_e2e_ms: 40.0,
        deadline_slack_us_per_token: 250,
    }
}

fn main() {
    let cfg = VirtualConfig::default();
    let scenarios = [
        ("poisson 600rps", ArrivalProcess::Poisson { rate_rps: 600.0 }),
        (
            "bursty 2000rps 10/30ms",
            ArrivalProcess::Bursty {
                rate_rps: 2000.0,
                mean_on_ms: 10.0,
                mean_off_ms: 30.0,
            },
        ),
    ];
    for (name, arrival) in scenarios {
        let spec = spec(arrival);
        println!("\n== {name} ({} requests, SLO {} ms e2e) ==", spec.requests,
                 spec.slo_e2e_ms);
        println!("{:<6} {:>10} {:>10} {:>10} {:>9} {:>10} {:>8}", "policy",
                 "p50 e2e", "p95 e2e", "p99 e2e", "SLO att.", "tok/s",
                 "queue99");
        for policy in [
            AdmissionPolicy::fifo(),
            AdmissionPolicy::sjf(),
            AdmissionPolicy::deadline(),
        ] {
            let out = run_virtual(&cfg, &spec, policy);
            let s = report::summarize(&spec, &out);
            println!(
                "{:<6} {:>8.2}ms {:>8.2}ms {:>8.2}ms {:>8.1}% {:>10.0} \
                 {:>6.2}ms",
                policy.label(),
                s.e2e.quantile(0.5) / 1e3,
                s.e2e.quantile(0.95) / 1e3,
                s.e2e.quantile(0.99) / 1e3,
                s.attainment * 100.0,
                s.tokens_per_s,
                s.queue.quantile(0.99) / 1e3,
            );
            assert_eq!(
                s.completed + s.errored,
                spec.requests as u64,
                "every request must end terminally"
            );
        }
    }
    println!(
        "\n(virtual clock: rerunning this example reproduces these \
         numbers byte-for-byte; see `moepim loadtest` for the JSON report)"
    );
}
