//! Area study: how peripheral sharing trades area against contention, and
//! how the crossbar-area ratio moves the optimal group size (§IV-B's
//! generalisation to ISAAC-like peripheral-heavy designs).
//!
//! ```bash
//! cargo run --release --example area_sweep
//! ```

use moepim::config::{HardwareConfig, MoeModelConfig};
use moepim::eval::sweep;
use moepim::hw::AreaModel;
use moepim::moe::LayerLayout;

fn main() {
    let model = MoeModelConfig::llama_moe_4_16();

    println!("static area model (1536 crossbars, 2-D layout):");
    for ratio in [0.40, 0.05] {
        let mut hw = HardwareConfig::paper();
        hw.xbar_area_ratio = ratio;
        let layout = LayerLayout::new(&model, &hw);
        let area = AreaModel::new(&hw);
        println!("  crossbar ratio {:.0}%:", ratio * 100.0);
        for g in [1usize, 2, 4, 8] {
            println!(
                "    g={g}: {:>7.1} mm²  ({:.2}x saving)",
                area.moe_area_mm2(&layout, g),
                area.saving_vs_baseline(&layout, g)
            );
        }
    }

    println!("\ndynamic sweep (workload-driven GOPS/mm², S-grouping + O):");
    print!("{}", sweep::render());

    let p = sweep::isaac_point();
    println!(
        "\nISAAC-like operating point (ratio 5%, g=4): {:.1} GOPS/mm² \
         (paper quotes 82.7)",
        p.gops_per_mm2
    );
}
