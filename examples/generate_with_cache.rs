//! The Fig. 4 story as a runnable example: sweep the four cache regimes
//! over the generation stage and show how the KVGO combination wins, with
//! the per-step breakdown that explains *why* (attention vs linear vs DRAM).
//!
//! ```bash
//! cargo run --release --example generate_with_cache -- [gen_len]
//! ```

use moepim::config::{CachePolicy, SimConfig};
use moepim::eval::fig4;
use moepim::sim::Simulator;

fn main() {
    let gen_len: usize = std::env::args()
        .nth(1)
        .and_then(|a| a.parse().ok())
        .unwrap_or(8);

    print!("{}", fig4::render_fig4a(gen_len));

    // Per-step anatomy of the winning configuration.
    let mut cfg = SimConfig::baseline();
    cfg.cache = CachePolicy::KVGO;
    cfg.gen_len = gen_len;
    let r = Simulator::paper(cfg).run();
    println!("\nKVGO per-step anatomy (first/last step):");
    for (name, s) in [
        ("first", r.decode_steps.first().unwrap()),
        ("last", r.decode_steps.last().unwrap()),
    ] {
        println!(
            "  {name:>5}: {:>8.0} ns  (attn {:>6.0}, linear {:>6.0}, dram \
             {:>6.0})  {:>7.0} nJ",
            s.latency_ns,
            s.breakdown.attn_ns,
            s.breakdown.gate_ns + s.breakdown.moe_ns,
            s.breakdown.dram_ns,
            s.energy_nj,
        );
    }

    println!("\nscaling with generated length (Fig 4b):");
    print!("{}", fig4::render_fig4b());
}
