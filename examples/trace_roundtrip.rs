//! E11 driver: the trace lifecycle end to end — record a virtual run,
//! round-trip the `moepim.trace.v1` document through its JSON text,
//! replay it byte-identically, then calibrate the virtual cost model
//! against the recording and print the fit.
//!
//! The same loop the CLI exposes as `loadtest --record FILE`,
//! `loadtest --replay FILE`, and `calibrate --trace FILE`, driven here
//! in-process so the identity and the fit are visible side by side.
//!
//! ```bash
//! cargo run --release --example trace_roundtrip
//! ```

use moepim::util::json;
use moepim::workload::record::{RecordedTrace, TraceBackend, TraceRecorder};
use moepim::workload::{
    calibrate, report, run_virtual, run_virtual_requests, scenario_spec,
    AdmissionPolicy, VirtualConfig,
};

fn main() {
    let cfg = VirtualConfig::default();
    let policy = AdmissionPolicy::fifo();
    let spec = scenario_spec("mixed-tenants", 2026).expect("known preset");
    println!(
        "E11: trace lifecycle on the mixed-tenants preset ({} requests, \
         seed {})",
        spec.requests, spec.seed
    );

    // ---- record -----------------------------------------------------------
    let out = run_virtual(&cfg, &spec, policy);
    let recorded = report::build(&spec, policy, &out).to_string_pretty();
    let trace = TraceRecorder::new(&spec, policy)
        .finish(&out, TraceBackend::from_virtual(&cfg));
    let text = trace.to_json().to_string_pretty();
    println!(
        "recorded {} requests -> {} bytes of moepim.trace.v1",
        trace.requests.len(),
        text.len()
    );

    // ---- reload + replay --------------------------------------------------
    let doc = json::parse(&text).expect("trace text parses");
    let loaded = RecordedTrace::from_json(&doc).expect("trace loads");
    assert_eq!(loaded, trace, "JSON round trip must be lossless");
    let replay = run_virtual_requests(
        &cfg,
        loaded.original_spec(),
        &loaded.replay_requests(),
        policy,
    );
    let replayed = report::build(loaded.original_spec(), policy, &replay)
        .to_string_pretty();
    println!(
        "replay report: {} bytes, byte-identical to the recording: {}",
        replayed.len(),
        replayed == recorded
    );
    assert_eq!(replayed, recorded);

    // ---- calibrate --------------------------------------------------------
    let cal = calibrate(&loaded, &cfg).expect("calibration fit");
    println!(
        "calibration over {} samples (mean {:.2} planner cycles/step):",
        cal.n_samples, cal.mean_cycles_per_step
    );
    println!(
        "  prefill_ns_per_token : fitted {:>8.1}  (base {})",
        cal.prefill_ns_per_token, cal.base.prefill_ns_per_token
    );
    println!(
        "  decode_step_ns       : fitted {:>8.1}  (scale {:.4} applied \
         to dispatch {} + cycle {})",
        cal.decode_step_ns,
        cal.scale,
        cal.base.dispatch_overhead_ns,
        cal.base.cycle_ns
    );
    println!(
        "  fit residual         : {:.1} us rms over service times",
        cal.rms_residual_us
    );
    println!(
        "  re-prediction        : p50 {:.1} us vs {:.1} us ({:.2}% err), \
         p99 {:.1} us vs {:.1} us ({:.2}% err)",
        cal.predicted_p50_e2e_us,
        cal.recorded_p50_e2e_us,
        cal.p50_err_pct,
        cal.predicted_p99_e2e_us,
        cal.recorded_p99_e2e_us,
        cal.p99_err_pct
    );
    assert!(
        cal.p50_err_pct <= 15.0 && cal.p99_err_pct <= 15.0,
        "self-calibration must land inside the 15% acceptance gate"
    );
    println!("E11 OK: record -> replay byte-identical, fit inside 15%");
}
