//! End-to-end validation driver (DESIGN.md E7): load the real AOT-compiled
//! MoE transformer block, serve a batch of generation requests through the
//! threaded coordinator (KV + GO caches on the hot path), verify the
//! GO-cached stream against the uncached recompute reference, and report
//! latency/throughput — recorded in EXPERIMENTS.md.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_moe
//! ```

use std::path::Path;

use moepim::coordinator::{DecodeMode, ModelEngine, Request, Server};
use moepim::runtime::Runtime;
use moepim::util::rng::Pcg32;

fn prompt(len: usize, seed: u64, vocab: usize) -> Vec<i32> {
    let mut rng = Pcg32::new(seed);
    (0..len).map(|_| rng.gen_range(vocab) as i32).collect()
}

fn artifacts_dir() -> std::path::PathBuf {
    std::env::var("MOEPIM_ARTIFACTS")
        .map(Into::into)
        .unwrap_or_else(|_| {
            Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
        })
}

fn main() -> anyhow::Result<()> {
    let dir = artifacts_dir();
    println!("loading artifacts from {}", dir.display());

    // ---- correctness first: cached decode == recompute reference -------
    // (a depth-1 statement — at L >= 2 a batch re-route rewrites past
    // tokens' mid-stack hiddens the cached path froze, so deep sets are
    // pinned by the batched-vs-per-session suites instead)
    let rt = Runtime::load(&dir)?;
    println!("platform {}, {} executables compiled", rt.platform(),
             rt.n_executables());
    let engine = ModelEngine::new(rt);
    let vocab = engine.model.vocab;
    let p = prompt(engine.model.prompt_len, 42, vocab);
    let cached = engine.generate(&p, 12, DecodeMode::Cached)?;
    if engine.model.n_layers == 1 {
        let reference = engine.generate(&p, 12, DecodeMode::Recompute)?;
        assert_eq!(
            cached.tokens, reference.tokens,
            "GO-cached decode must reproduce the full-recompute reference"
        );
        println!(
            "equivalence OK over 12 tokens: {:?}\n  cached decode {:.1} ms \
             vs recompute {:.1} ms ({:.2}x functional speedup)",
            cached.tokens,
            cached.decode_us / 1e3,
            reference.decode_us / 1e3,
            reference.decode_us / cached.decode_us
        );
    } else {
        println!(
            "cached decode over {} layers: {:?} ({:.1} ms; recompute \
             equivalence is depth-1-only, skipped)",
            engine.model.n_layers,
            cached.tokens,
            cached.decode_us / 1e3
        );
    }
    drop(engine);

    // ---- then throughput: slot-batched serving --------------------------
    let server = Server::spawn(dir)?;
    let n_requests = 8;
    let gen_len = 16;
    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = (0..n_requests)
        .map(|i| {
            server.submit(Request::new(i, prompt(32, 100 + i, vocab),
                                       gen_len))
        })
        .collect();
    let mut total_tokens = 0;
    let mut ttft_sum = 0.0;
    let mut lat_sum = 0.0;
    for rx in rxs {
        let resp = rx.recv()?;
        let tokens = resp
            .result
            .as_ref()
            .map_err(|e| anyhow::anyhow!("request {} failed: {e}", resp.id))?;
        total_tokens += tokens.len();
        // a successful response always carries real admission/TTFT times
        ttft_sum += resp.ttft_us.unwrap_or(0.0);
        lat_sum += resp.latency_us;
        println!(
            "  req {:>2}: {:>2} tokens  ttft {:>7.1} ms  latency {:>7.1} ms  \
             ({} batched / {} single steps, queued {:.1} ms)",
            resp.id,
            tokens.len(),
            resp.ttft_us.unwrap_or(0.0) / 1e3,
            resp.latency_us / 1e3,
            resp.batched_steps,
            resp.single_steps,
            resp.queue_us.unwrap_or(0.0) / 1e3,
        );
    }
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "\nserved {n_requests} requests / {total_tokens} tokens in \
         {wall:.2} s\n  throughput {:.1} tok/s | mean ttft {:.1} ms | mean \
         latency {:.1} ms",
        total_tokens as f64 / wall,
        ttft_sum / n_requests as f64 / 1e3,
        lat_sum / n_requests as f64 / 1e3,
    );

    // ---- serving telemetry: batching + peripheral contention ------------
    let stats = server.stats()?;
    println!(
        "slots {} | {} batched dispatches (mean occupancy {:.2}) | {} \
         single-token dispatches | peak waiting {}",
        stats.slots,
        stats.batch_dispatches,
        stats.mean_batch_occupancy(),
        stats.single_dispatches,
        stats.peak_waiting,
    );
    let p = stats.planner;
    println!(
        "planner: {} steps, {} work items, {} cycles ({:.1}% from \
         peripheral contention), {} activation transfers",
        p.steps,
        p.work,
        p.cycles,
        p.contention_ratio() * 100.0,
        p.transfers,
    );
    Ok(())
}
