//! E9 driver: the multi-server placement study — shard count × placement
//! policy on the virtual clock, under identical seeded traffic.
//!
//! One `WorkloadSpec` is materialized once per cell; the `ShardedDriver`
//! splits it across N virtual clusters under each placement policy and
//! merges shard-exactly, so every row of a block saw byte-identical
//! requests and any difference is the placement (and the parallelism N
//! buys) alone.  The table reads off the trade the ROADMAP's
//! "multi-server sharding" item asks about: how much merged-p99 each
//! policy leaves on the table vs how evenly it spreads load.
//!
//! ```bash
//! cargo run --release --example shard_placement
//! ```

use moepim::workload::{
    report, shard, AdmissionPolicy, ArrivalProcess, PlacementPolicy,
    ShardedDriver, SizeModel, VirtualConfig, WorkloadSpec,
};

fn spec() -> WorkloadSpec {
    WorkloadSpec {
        seed: 9,
        requests: 160,
        arrival: ArrivalProcess::Poisson { rate_rps: 3_000.0 },
        sizes: SizeModel::TraceSeeded {
            n_experts: 16,
            skew: 1.2,
            prompt: (4, 24),
            gen: (1, 12),
        },
        slo_e2e_ms: 30.0,
        deadline_slack_us_per_token: 250,
    }
}

fn main() {
    let cfg = VirtualConfig::default();
    let spec = spec();
    let policy = AdmissionPolicy::fifo();
    let placements = [
        PlacementPolicy::RoundRobin,
        PlacementPolicy::least_outstanding(&cfg),
        PlacementPolicy::SizeHash,
        PlacementPolicy::route_aware(&cfg),
    ];
    println!(
        "placement study: {} requests, poisson 3000 rps, SLO {} ms e2e, \
         FIFO admission per shard",
        spec.requests, spec.slo_e2e_ms
    );
    for shards in [1usize, 2, 4, 8] {
        println!("\n== {shards} shard(s) ==");
        println!(
            "{:<18} {:>9} {:>9} {:>9} {:>7} {:>10} {:>8} {:>9}",
            "placement", "p50 e2e", "p99 e2e", "gap p99", "load",
            "tok/s", "SLO", "contention"
        );
        for placement in placements {
            let driver = ShardedDriver::new(shards, placement);
            let run = driver.run_virtual(&cfg, &spec, policy);
            let (merged, imb) = shard::analyze(&spec, &run.shards);
            let total: usize =
                run.shards.iter().map(|s| s.outcome.samples.len()).sum();
            assert_eq!(total, spec.requests, "a request was lost or duplicated");
            println!(
                "{:<18} {:>7.2}ms {:>7.2}ms {:>7.2}ms {:>6.2}x {:>10.0} \
                 {:>7.1}% {:>8.1}%",
                placement.label(),
                merged.summary.e2e.quantile(0.5) / 1e3,
                merged.summary.e2e.quantile(0.99) / 1e3,
                imb.p99_gap_us / 1e3,
                imb.load_ratio,
                merged.summary.tokens_per_s,
                merged.summary.attainment * 100.0,
                merged.planner.contention_ratio() * 100.0,
            );
        }
    }

    // one full merged v2 document, to show the report surface
    let driver = ShardedDriver::new(
        4,
        PlacementPolicy::route_aware(&cfg),
    );
    let run = driver.run_virtual(&cfg, &spec, policy);
    let doc = report::build_sharded(&spec, policy, &driver, &run);
    let text = doc.to_string_pretty();
    let parsed =
        moepim::util::json::parse(&text).expect("v2 report parses");
    println!(
        "\nmerged v2 report (4 shards, route-aware): schema={} \
         shards[]={} imbalance.load_ratio={}",
        parsed.path(&["schema"]).unwrap().as_str().unwrap(),
        parsed.path(&["shards"]).unwrap().as_arr().unwrap().len(),
        parsed
            .path(&["imbalance", "load_ratio"])
            .unwrap()
            .as_f64()
            .unwrap(),
    );
    println!(
        "(virtual clock: rerunning this example reproduces every number \
         byte-for-byte; see `moepim shardtest` for the full JSON)"
    );
}
