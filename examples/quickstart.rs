//! Quickstart: simulate one MoE inference on the paper's hardware and
//! print the latency/energy/area report.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use moepim::config::SimConfig;
use moepim::sim::Simulator;
use moepim::util::fmt_thousands;

fn main() {
    // The paper's best configuration: sorted grouping of 2 experts per
    // peripheral set, Algorithm-1 reschedule, KV + GO caches.
    let cfg = SimConfig::s2o_kvgo();
    println!("simulating Llama-MoE-4/16 on HERMES cores: {}", cfg.label());

    let report = Simulator::paper(cfg).run();
    let total = report.total();

    println!("\n  prefill : {:>12} ns",
             fmt_thousands(report.prefill.latency_ns.round() as u64));
    println!("  decode  : {:>12} ns ({} tokens)",
             fmt_thousands(report.decode_total().latency_ns.round() as u64),
             report.decode_steps.len());
    println!("  total   : {:>12} ns / {} nJ",
             fmt_thousands(total.latency_ns.round() as u64),
             fmt_thousands(total.energy_nj.round() as u64));
    println!("  MoE area: {:.1} mm² (2-D layout, linear cores only)",
             report.moe_area_mm2);
    println!("  density : {:.1} GOPS/W/mm²", report.density());

    // Compare against the 3DCIM-style baseline (no sharing, no schedule,
    // no caches).
    let base = Simulator::paper(SimConfig::baseline()).run();
    let bt = base.total();
    println!("\nvs baseline (no cache, no schedule):");
    println!("  latency {:.2}x, energy {:.2}x, area {:.2}x",
             bt.latency_ns / total.latency_ns,
             bt.energy_nj / total.energy_nj,
             base.moe_area_mm2 / report.moe_area_mm2);
}
