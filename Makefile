# Repo driver: python AOT artifacts + rust build/test.
#
#   make artifacts   lower the functional model to rust/artifacts/*.hlo.txt
#                    (LAYERS=n overrides the functional depth; the CI
#                    matrix builds LAYERS=1 and LAYERS=3 sets)
#   make build       release build of the rust crate
#   make test        tier-1 gate (build + tests; artifacts required first)
#   make bench       hot-path benchmarks (incl. batched-vs-round-robin decode)

PY ?= python3
LAYERS ?= 1

.PHONY: artifacts build test bench clean

artifacts:
	cd python && $(PY) -m compile.aot --out ../rust/artifacts --layers $(LAYERS)

build:
	cd rust && cargo build --release

test:
	cd rust && cargo build --release && cargo test -q

bench:
	cd rust && cargo bench --bench hotpath

clean:
	rm -rf rust/target rust/artifacts
